"""Quantized-LUT fast path: uint8 ADC end to end.

Covers the quantization primitives (error bound, kernel/host agreement),
the Pallas uint8 scan variants, recall@10 parity vs f32 at paper configs,
byte-budgeted caching with quantized entries, the serving invariants on
the uint8 path (warm-cache repeats bit-identical, padding rows bypass
cache/heat/stats), and the spec wiring.

Comparison idiom (repo convention): ids via ``assert_array_equal`` and
distances via ``allclose(rtol=1e-5)`` only where both sides are the SAME
f32 pipeline; quantized-vs-f32 results are compared via recall /
neighbor-set overlap, never by distance values.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SearchParams, adc_distances,
                        adc_distances_quantized, dequantize_lut,
                        quantize_lut, recall_at_k, search_ivfpq)
from repro.kernels import ops
from repro.runtime import HotClusterLUTCache, entry_nbytes

NPROBE = 8


def _mk(seed, t, m, cb, c, dsub):
    rng = np.random.default_rng(seed)
    res = rng.normal(size=(t, m * dsub)).astype(np.float32)
    books = rng.normal(size=(m, cb, dsub)).astype(np.float32)
    sqn = (books * books).sum(-1)
    codes = rng.integers(0, cb, size=(t, c, m)).astype(np.int32)
    ids = rng.integers(0, 1 << 20, size=(t, c)).astype(np.int32)
    sizes = rng.integers(1, c + 1, size=(t,)).astype(np.int32)
    return tuple(map(jnp.asarray, (res, books, sqn, codes, ids, sizes)))


# ---------------------------------------------------------------------------
# Quantization primitives
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bound():
    """|dequant - lut| <= scale/2 per entry (half a quantization step),
    and degenerate (constant) subspaces roundtrip exactly."""
    res, books, sqn, *_ = _mk(0, 9, 8, 64, 4, 4)
    lut = ops.lut_build(res, books, sqn)
    qlut = quantize_lut(lut)
    err = np.abs(np.asarray(dequantize_lut(qlut)) - np.asarray(lut))
    bound = np.asarray(qlut.scale)[..., None] * 0.5
    assert (err <= bound * (1 + 1e-5) + 1e-6).all()
    flat = jnp.full((1, 4, 16), 3.25, jnp.float32)       # constant subspace
    qflat = quantize_lut(flat)
    np.testing.assert_array_equal(np.asarray(qflat.lut_q), 0)
    np.testing.assert_array_equal(np.asarray(dequantize_lut(qflat)),
                                  np.asarray(flat))


def test_lut_build_q_kernel_matches_host_quantize():
    """The fused quantize epilogue agrees with host-side quantize_lut of
    the kernel's f32 output.  Entries sitting exactly on a rounding
    boundary may flip by one count (in-kernel fusion reassociates the
    affine transform), so the contract is |diff| <= 1 count — i.e. the
    dequantized tables agree to within one quantization step."""
    res, books, sqn, *_ = _mk(1, 30, 16, 256, 4, 8)
    qk = ops.lut_build_q(res, books, sqn)
    qh = quantize_lut(ops.lut_build(res, books, sqn))
    diff = (np.asarray(qk.lut_q).astype(np.int32)
            - np.asarray(qh.lut_q).astype(np.int32))
    assert np.abs(diff).max() <= 1
    assert (diff != 0).mean() < 1e-3          # boundary flips only
    np.testing.assert_allclose(np.asarray(qk.scale), np.asarray(qh.scale),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(qk.bias), np.asarray(qh.bias),
                               rtol=1e-6)


@pytest.mark.parametrize("strategy", ["gather", "onehot"])
def test_quantized_scan_matches_dequantized_reference(strategy):
    """adc_distances_quantized == adc_distances over the dequantized
    table (the ISSUE's 'reference dequantized scan' contract)."""
    res, books, sqn, codes, ids, sizes = _mk(2, 5, 8, 64, 300, 4)
    qlut = quantize_lut(ops.lut_build(res, books, sqn))
    got = np.asarray(adc_distances_quantized(qlut, codes, sizes, strategy))
    want = np.asarray(adc_distances(dequantize_lut(qlut), codes, sizes,
                                    strategy))
    valid = np.arange(codes.shape[1])[None] < np.asarray(sizes)[:, None]
    np.testing.assert_allclose(got[valid], want[valid], rtol=1e-4, atol=1e-3)
    assert np.isinf(got[~valid]).all()


@pytest.mark.parametrize("t,m,cb,c", [(1, 4, 16, 32), (3, 8, 64, 300),
                                      (8, 16, 256, 512)])
@pytest.mark.parametrize("strategy", ["gather", "onehot"])
def test_pq_scan_dc_q_kernel_sweep(t, m, cb, c, strategy):
    res, books, sqn, codes, ids, sizes = _mk(3, t, m, cb, c, 4)
    qlut = ops.lut_build_q(res, books, sqn)
    got = np.asarray(ops.pq_scan_dc(qlut, codes, sizes, strategy=strategy))
    want = np.asarray(adc_distances_quantized(qlut, codes, sizes, "gather"))
    valid = np.arange(c)[None] < np.asarray(sizes)[:, None]
    np.testing.assert_allclose(got[valid], want[valid], rtol=1e-4, atol=1e-3)
    assert np.isinf(got[~valid]).all()


@pytest.mark.parametrize("strategy", ["gather", "onehot"])
def test_pq_scan_topk_q_kernel(strategy):
    """Fused u8 kernel == full quantized scan + top-k (distances allclose;
    equal-distance ties may permute ids, so compare id multisets)."""
    res, books, sqn, codes, ids, sizes = _mk(4, 5, 8, 64, 300, 4)
    qlut = ops.lut_build_q(res, books, sqn)
    k = 10
    gd, gi = ops.pq_scan_topk(qlut, codes, ids, sizes, k, strategy=strategy)
    full = adc_distances_quantized(qlut, codes, sizes, "gather")
    rd, ridx = jax.lax.top_k(-full, k)
    np.testing.assert_allclose(np.asarray(gd), np.asarray(-rd),
                               rtol=1e-4, atol=1e-3)
    # quantization makes exact distance ties common, and tie-breaking may
    # differ between the streaming kernel and a full-scan top-k — compare
    # id multisets with tolerance for boundary ties only
    want_ids = np.take_along_axis(
        np.where(np.isfinite(np.asarray(full)), np.asarray(ids), -1),
        np.asarray(ridx), axis=1)
    for t_ in range(gi.shape[0]):
        overlap = len(set(np.asarray(gi)[t_].tolist())
                      & set(want_ids[t_].tolist()))
        assert overlap >= k - 2, (t_, overlap)


# ---------------------------------------------------------------------------
# Recall parity at paper configs (synthetic SIFT-like corpus)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernels", [False, True])
def test_recall_parity_local(small_index, small_clusters, small_corpus,
                             use_kernels):
    """recall@10 drop <= 0.01 vs the f32 path, both DC strategies."""
    for strategy in ("gather", "onehot"):
        pf = SearchParams(nprobe=NPROBE, k=10, strategy=strategy,
                          query_chunk=32, use_kernels=use_kernels)
        pu = pf._replace(lut_dtype="uint8")
        _, i_f = search_ivfpq(small_index, small_clusters,
                              small_corpus.queries, pf)
        _, i_u = search_ivfpq(small_index, small_clusters,
                              small_corpus.queries, pu)
        rf = float(recall_at_k(i_f, small_corpus.groundtruth))
        ru = float(recall_at_k(i_u, small_corpus.groundtruth))
        assert rf - ru <= 0.01, (strategy, use_kernels, rf, ru)


def test_recall_parity_sharded(small_index, small_corpus):
    from repro.core import cluster_locate
    from repro.core.sharded_search import DistributedEngine, EngineConfig
    probes, _ = cluster_locate(small_corpus.queries.astype(jnp.float32),
                               small_index.centroids, NPROBE)
    sample = np.asarray(probes)
    queries = jnp.asarray(small_corpus.queries[:32], jnp.float32)
    gt = small_corpus.groundtruth[:32]
    recalls = {}
    for dtype in ("f32", "uint8"):
        cfg = EngineConfig(n_shards=4, nprobe=NPROBE, k=10,
                           tasks_per_shard=512, strategy="gather",
                           lut_dtype=dtype)
        eng = DistributedEngine(small_index, cfg, sample)
        _, i, _ = eng.search(queries)
        recalls[dtype] = float(recall_at_k(jnp.asarray(i), gt))
    assert recalls["f32"] - recalls["uint8"] <= 0.01, recalls


# ---------------------------------------------------------------------------
# Byte-budgeted cache with quantized entries
# ---------------------------------------------------------------------------

def test_cache_byte_budget_and_quantized_capacity():
    """At a fixed byte budget the uint8 cache holds ~4x the entries, and
    neither cache ever exceeds the budget; stats report bytes+entries."""
    m, cb = 16, 256
    f32_entry = np.zeros((m, cb), np.float32)
    u8_entry = (np.zeros((m, cb), np.uint8), np.zeros(m, np.float32),
                np.zeros(m, np.float32))
    assert entry_nbytes(f32_entry) == m * cb * 4
    assert entry_nbytes(u8_entry) == m * cb + 8 * m
    budget = 16 * entry_nbytes(f32_entry)
    caches = {}
    for dtype, entry in (("f32", f32_entry), ("uint8", u8_entry)):
        cache = HotClusterLUTCache(capacity=None, capacity_bytes=budget,
                                   lut_dtype=dtype)
        for i in range(100):
            cache.put_by_bucket(i, 0, entry)
            assert cache.bytes <= budget
        caches[dtype] = cache
    assert len(caches["uint8"]) >= 3 * len(caches["f32"])
    stats = caches["uint8"].stats.as_dict()
    assert stats["entries"] == len(caches["uint8"])
    assert stats["bytes"] == caches["uint8"].bytes > 0


def test_byte_budget_rejection_leaves_cache_untouched():
    """A byte-budget insert that admission ultimately rejects must not
    evict anything along the way: the full victim set is selected before
    the cache is mutated (HeatAwareAdmission contract — one-off cold
    probes cannot churn resident hot-cluster LUTs)."""
    from repro.runtime import HeatAwareAdmission, LRUCache, \
        OnlineHeatEstimator
    est = OnlineHeatEstimator(nlist=4, halflife_batches=1e9)
    for _ in range(4):
        est.observe(np.array([[1], [2]]))       # clusters 1,2 hot; 0,3 cold
    lru = LRUCache(capacity=None, capacity_bytes=100,
                   admission=HeatAwareAdmission(est))
    lru.put((0, 0), np.zeros(5, np.float32))    # cold, 20 B, oldest
    lru.put((1, 0), np.zeros(10, np.float32))   # hot, 40 B
    lru.put((2, 0), np.zeros(10, np.float32))   # hot, 40 B
    before = (len(lru), lru.bytes, lru.stats.evictions,
              list(lru._od.keys()))
    # cold 60 B insert needs two victims; the second pick rejects
    assert not lru.put((3, 0), np.zeros(15, np.float32))
    after = (len(lru), lru.bytes, lru.stats.evictions,
             list(lru._od.keys()))
    assert before == after and lru.stats.rejects == 1


def test_cache_rejects_oversized_and_validates_dtype():
    with pytest.raises(ValueError):
        HotClusterLUTCache(lut_dtype="f16")
    with pytest.raises(ValueError):
        HotClusterLUTCache(capacity=None)          # no bound at all
    cache = HotClusterLUTCache(capacity=None, capacity_bytes=64)
    assert not cache._lru.put(("k",), np.zeros(128, np.float32))
    assert cache.stats.rejects == 1 and len(cache) == 0


def test_engine_rejects_dtype_mismatch(small_index, small_clusters):
    from repro.runtime.serving import LocalEngine, service_construction
    with service_construction():
        with pytest.raises(ValueError):
            LocalEngine(small_index, small_clusters,
                        SearchParams(nprobe=4, k=5, lut_dtype="uint8"),
                        lut_cache=HotClusterLUTCache(capacity=64))


# ---------------------------------------------------------------------------
# Serving invariants on the uint8 path
# ---------------------------------------------------------------------------

def _local_u8(small_index, small_clusters, cache):
    from repro.runtime.serving import LocalEngine, service_construction
    with service_construction():
        return LocalEngine(small_index, small_clusters,
                           SearchParams(nprobe=NPROBE, k=10,
                                        lut_dtype="uint8"),
                           lut_cache=cache)


def test_warm_cache_repeat_bit_identical(small_index, small_clusters,
                                         small_corpus):
    """Same batch twice with a warm quantized cache: the second pass is
    served entirely from cached (lut_q, scale, bias) triples, so ids AND
    distances are bit-identical."""
    cache = HotClusterLUTCache(capacity=4096, lut_dtype="uint8")
    eng = _local_u8(small_index, small_clusters, cache)
    q = np.asarray(small_corpus.queries[:16], np.float32)
    d1, i1 = eng.search_batch(q)
    assert cache.stats.hits == 0
    d2, i2 = eng.search_batch(q)
    assert cache.stats.hit_rate > 0.4          # second pass all hits
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(d1, d2)


def test_padding_bypasses_cache_on_u8_path(small_index, small_clusters,
                                           small_corpus):
    """Rows >= n_valid are serving padding: never looked up, never
    inserted, invisible to stats — exactly as on the f32 path."""
    cache = HotClusterLUTCache(capacity=4096, lut_dtype="uint8")
    eng = _local_u8(small_index, small_clusters, cache)
    q = np.zeros((8, small_corpus.queries.shape[1]), np.float32)
    q[:3] = np.asarray(small_corpus.queries[:3], np.float32)
    eng.search_batch(q, n_valid=3)
    assert cache.stats.lookups == 3 * NPROBE
    assert len(cache) <= 3 * NPROBE
    eng.search_batch(q, n_valid=0)             # warmup-style: all padding
    assert cache.stats.lookups == 3 * NPROBE   # unchanged


def test_sharded_u8_cache_padding_and_repeat(small_index, small_corpus):
    from repro.core import cluster_locate
    from repro.core.sharded_search import DistributedEngine, EngineConfig
    probes, _ = cluster_locate(small_corpus.queries.astype(jnp.float32),
                               small_index.centroids, NPROBE)
    cache = HotClusterLUTCache(capacity=4096, lut_dtype="uint8")
    cfg = EngineConfig(n_shards=4, nprobe=NPROBE, k=10, tasks_per_shard=512,
                       strategy="gather", lut_dtype="uint8")
    eng = DistributedEngine(small_index, cfg, np.asarray(probes),
                            lut_cache=cache)
    q = jnp.asarray(small_corpus.queries[:8], jnp.float32)
    d1, i1, _ = eng.search(q, n_valid=4)       # 4 pad rows
    assert cache.stats.lookups == 4 * NPROBE
    d2, i2, _ = eng.search(q, n_valid=4)
    np.testing.assert_array_equal(i1[:4], i2[:4])
    np.testing.assert_array_equal(d1[:4], d2[:4])
    assert cache.stats.hits > 0


def test_runtime_serving_matches_direct_u8(small_index, small_clusters,
                                           small_corpus):
    """De-padded streamed results == a direct batched call on the same
    engine (row-wise invariance holds for the quantized path too)."""
    from repro.runtime.serving import ServingConfig, ServingRuntime, \
        service_construction
    cache = HotClusterLUTCache(capacity=4096, lut_dtype="uint8")
    eng = _local_u8(small_index, small_clusters, cache)
    with service_construction():
        rt = ServingRuntime(eng, ServingConfig(buckets=(1, 2, 4),
                                               max_wait_s=1e-3))
    rt.warmup(small_corpus.queries.shape[1])
    assert cache.stats.lookups == 0            # warmup never touches it
    q = np.asarray(small_corpus.queries[:6], np.float32)
    reqs = rt.run_stream([(i * 1e-3, q[i]) for i in range(6)])
    direct_d, direct_i = eng.search_batch(q)
    np.testing.assert_array_equal(np.stack([r.ids for r in reqs]), direct_i)


# ---------------------------------------------------------------------------
# Spec / service wiring
# ---------------------------------------------------------------------------

def test_spec_validation_u8():
    from repro.service import ServiceSpec
    with pytest.raises(ValueError, match="lut_dtype"):
        ServiceSpec(lut_dtype="int8").validate()
    with pytest.raises(ValueError, match="cache_capacity_bytes"):
        ServiceSpec(cache_capacity_bytes=-1).validate()
    with pytest.raises(ValueError, match="heat_aware_admission"):
        ServiceSpec(heat_aware_admission=True).validate()
    spec = ServiceSpec(lut_dtype="uint8", cache_capacity_bytes=1 << 20)
    spec.validate()
    assert spec.cache_enabled
    assert not ServiceSpec().cache_enabled


def test_service_u8_end_to_end(small_index, small_corpus):
    """AnnService with lut_dtype=uint8 + byte-budgeted cache: neighbor
    overlap with the f32 service >= 0.9 and cache bytes stay in budget."""
    from repro.service import AnnService, ServiceSpec
    q = np.asarray(small_corpus.queries[:16], np.float32)
    base = dict(engine="local", replicas=1, nprobe=NPROBE, k=10,
                buckets=(1, 2, 4), max_wait_s=1e-3)
    svc_f = AnnService.build(ServiceSpec(**base), index=small_index)
    _, i_f = svc_f.search(q)
    svc_f.shutdown()
    budget = 1 << 20
    svc_u = AnnService.build(
        ServiceSpec(lut_dtype="uint8", cache_capacity_bytes=budget, **base),
        index=small_index)
    svc_u.warmup()
    _, i_u = svc_u.search(q)
    overlap = np.mean([len(set(i_u[r]) & set(i_f[r])) / 10.0
                       for r in range(len(q))])
    assert overlap >= 0.9, overlap
    cache = svc_u.replicas[0].cache
    assert cache.lut_dtype == "uint8"
    assert 0 < cache.bytes <= budget
    svc_u.shutdown()
