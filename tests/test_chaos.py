"""Fail-operational serving tests.

Groups:
  * fault injector — site validation, seed-pure decision sequences,
    replica filters / after / count caps, zero-rule near-no-op;
  * circuit breaker — closed -> open -> half-open probe -> closed/
    re-open, on a fake clock;
  * supervisor resume — failures restart from the latest *persisted*
    checkpoint, not the failure step (regression: checkpoint_steps was
    silently dropped);
  * degraded search — a tiny deadline forces resident-only scans and
    the degraded/deadline_missed flags surface in future.timing();
  * load shedding — a bounded queue rejects with ServiceOverloaded
    instead of queueing unboundedly behind a straggler;
  * maintenance death — a killed maintenance thread surfaces as an
    error on the next mutation API call, never silently;
  * chaos e2e — a reduced run of the canonical experiment
    (repro.service.chaos) holds the availability/exactness floors.
"""

import numpy as np
import pytest

from repro.runtime.fault_tolerance import (HeartbeatRegistry, ReplicaHealth,
                                           RunSupervisor)
from repro.runtime.faults import (FaultInjector, FaultPlan, FaultRule,
                                  InjectedFault, SITES)
from repro.runtime.serving import ServingConfig, ServingRuntime
from repro.service import AnnService, ServiceOverloaded, ServiceSpec

NPROBE = 8


def _build(small_index, injector=None, **spec_kwargs):
    defaults = dict(engine="local", nprobe=NPROBE, k=10,
                    buckets=(1, 2, 4), max_wait_s=1e-3)
    defaults.update(spec_kwargs)
    return AnnService.build(ServiceSpec(**defaults), index=small_index,
                            fault_injector=injector)


# -- fault injector ----------------------------------------------------------

def test_fault_rule_validation():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultRule("engine.btach")
    with pytest.raises(ValueError, match="rate"):
        FaultRule("engine.batch", rate=1.5)
    with pytest.raises(ValueError, match="count"):
        FaultRule("engine.batch", count=-1)
    with pytest.raises(ValueError, match="after"):
        FaultRule("engine.batch", after=-2)
    with pytest.raises(ValueError, match="delay_s"):
        FaultRule("engine.straggler", delay_s=-0.1)
    for site in SITES:                   # every named site constructs
        FaultRule(site)


def test_injector_decision_sequence_is_seed_pure():
    plan = FaultPlan(seed=7, rules=(FaultRule("engine.batch", rate=0.3),))
    a = FaultInjector(plan)
    b = FaultInjector(plan)
    sa = [a.fire("engine.batch") is not None for _ in range(200)]
    sb = [b.fire("engine.batch") is not None for _ in range(200)]
    assert sa == sb                      # same plan -> same sequence
    assert any(sa) and not all(sa)
    other = FaultInjector(FaultPlan(seed=8, rules=plan.rules))
    so = [other.fire("engine.batch") is not None for _ in range(200)]
    assert sa != so                      # seed actually matters
    st = a.stats()["engine.batch"]
    assert st["consultations"] == 200 and st["fires"] == sum(sa)


def test_injector_filters_after_count_replicas():
    plan = FaultPlan(seed=0, rules=(
        FaultRule("engine.batch", rate=1.0, count=2, after=3,
                  replicas=(1,)),))
    inj = FaultInjector(plan)
    # wrong replica: never consults, never fires
    assert all(inj.fire("engine.batch", replica=0) is None
               for _ in range(10))
    # right replica: first `after` consultations are clean, then
    # exactly `count` firings, then silence
    fires = [inj.fire("engine.batch", replica=1) is not None
             for _ in range(10)]
    assert fires == [False] * 3 + [True] * 2 + [False] * 5
    # unruled site: a single dict probe, no state
    assert inj.fire("tier.cold_read") is None
    assert "tier.cold_read" not in inj.stats()


def test_disarmed_service_reports_no_faults(small_index, small_corpus):
    svc = _build(small_index, replicas=1)
    svc.warmup()
    q = np.asarray(small_corpus.queries[:4], np.float32)
    svc.search(q)
    st = svc.stats()
    assert "faults" not in st
    assert st["aggregate"]["degraded"] == 0
    svc.shutdown()


# -- circuit breaker ---------------------------------------------------------

def test_breaker_full_state_machine():
    t = [0.0]
    h = ReplicaHealth(2, max_consecutive=2, half_open_after_s=10.0,
                      clock=lambda: t[0])
    assert h.state(0) == "closed" and h.allow(0)
    h.record_failure(0)
    assert h.state(0) == "closed"        # one short of the threshold
    h.record_failure(0)
    assert h.state(0) == "open" and not h.allow(0)
    assert h.open_count() == 1 and h.stats()["breaker"] == ["open",
                                                            "closed"]
    t[0] = 9.9
    assert not h.allow(0)                # window not yet reached
    t[0] = 10.0
    assert h.state(0) == "half_open"
    assert h.allow(0)                    # claims the single probe slot
    assert not h.allow(0)                # second router loses the race
    h.record_failure(0)                  # probe failed: re-open + re-arm
    assert h.state(0) == "open"
    t[0] = 15.0
    assert not h.allow(0)                # clock restarted at 10.0
    t[0] = 20.0
    assert h.allow(0)
    h.record_success(0)                  # probe succeeded: closed again
    assert h.state(0) == "closed" and h.allow(0)
    assert h.open_count() == 0


def test_breaker_releases_lost_probe_slot():
    """Regression: a claimed half-open probe whose request never reported
    back (executor scaled down / wedged, service shutdown) pinned
    _probing forever — allow() returned False indefinitely and the
    replica could never rejoin without an operator reset.  After a full
    half_open_after_s of silence the slot is released."""
    t = [0.0]
    h = ReplicaHealth(1, max_consecutive=1, half_open_after_s=10.0,
                      clock=lambda: t[0])
    h.record_failure(0)
    t[0] = 10.0
    assert h.allow(0)                    # probe claimed...
    assert not h.allow(0)                # ...slot pinned
    t[0] = 19.9                          # probe still plausibly in flight
    assert not h.allow(0)
    t[0] = 20.0                          # timed out: slot released
    assert h.allow(0)                    # a fresh probe is admitted
    assert not h.allow(0)                # and claims the single slot again
    h.record_success(0)                  # the fresh probe can still close
    assert h.state(0) == "closed" and h.allow(0)


def test_breaker_legacy_never_times_out():
    h = ReplicaHealth(1, max_consecutive=1)      # half_open_after_s=0
    h.record_failure(0)
    assert h.state(0) == "open" and not h.allow(0)
    h.record_success(0)                  # only success reopens
    assert h.allow(0)


# -- supervisor checkpoint resume --------------------------------------------

def test_supervisor_resumes_from_latest_checkpoint():
    """Regression: RunSupervisor used to drop checkpoint_steps on the
    floor and resume from the failure step — a step that was never
    persisted."""
    sup = RunSupervisor(data_axis=4, model_axis=4,
                        checkpoint_steps=(30, 10, 20))
    assert sup.checkpoint_steps == (10, 20, 30)    # stored, sorted
    assert sup._resume_step(27) == 20
    assert sup._resume_step(30) == 30
    assert sup._resume_step(5) == 0      # failure before any checkpoint
    # no schedule: legacy callers trust the failure step
    assert RunSupervisor(4, 4)._resume_step(27) == 27

    reg = HeartbeatRegistry(16, timeout_s=1e9)
    calls = []

    def run_fn(mesh_shape, start_step):
        calls.append(start_step)
        if len(calls) == 1:
            return "failed", 27
        return "done", 100

    assert sup.supervise(run_fn, reg) == 100
    assert calls == [0, 20]              # resumed from the checkpoint


# -- deadline-bounded degraded search ----------------------------------------

def test_deadline_degrades_and_flags(small_index, small_corpus, tmp_path):
    """An (effectively) zero deadline over a mostly-cold tier forces
    resident-only scans: requests complete, are flagged degraded in
    timing(), and the service counters agree."""
    svc = _build(small_index, replicas=1, storage="tiered",
                 storage_dir=str(tmp_path), storage_budget_bytes=1 << 16,
                 deadline_ms=1e-3)
    svc.warmup()
    q = np.asarray(small_corpus.queries[:8], np.float32)
    futs = [svc.submit_async(q[i]) for i in range(8)]
    degraded = 0
    for fut in futs:
        fut.result(timeout=30.0)
        t = fut.timing()
        assert {"degraded", "deadline_missed"} <= set(t)
        degraded += bool(t["degraded"])
    assert degraded > 0
    st = svc.stats()["aggregate"]
    assert st["degraded"] == degraded
    svc.shutdown()


def test_no_deadline_stays_exact(small_index, small_corpus, tmp_path):
    """deadline_ms=0 (off) over the same tier: nothing is degraded and
    tiered results equal the all-resident service's."""
    plain = _build(small_index, replicas=1)
    plain.warmup()
    q = np.asarray(small_corpus.queries[:8], np.float32)
    _, ref_ids = plain.search(q)
    plain.shutdown()
    svc = _build(small_index, replicas=1, storage="tiered",
                 storage_dir=str(tmp_path), storage_budget_bytes=1 << 16)
    svc.warmup()
    futs = [svc.submit_async(q[i]) for i in range(8)]
    for i, fut in enumerate(futs):
        _, ids = fut.result(timeout=30.0)
        np.testing.assert_array_equal(ids, np.asarray(ref_ids)[i])
        assert not fut.timing()["degraded"]
    assert svc.stats()["aggregate"]["degraded"] == 0
    svc.shutdown()


def test_straggler_sleep_charged_to_deadline_budget():
    """Regression: _serve computed budget_s before the injected
    straggler sleep, so under chaos the engine's degrade decision saw
    delay_s more budget than actually remained and could commit to a
    cold fetch that must miss the deadline."""
    seen = []

    class RecordingEngine:
        def search_batch(self, queries, n_valid=None, **kw):
            seen.append(kw.get("budget_s"))
            b = queries.shape[0]
            return (np.zeros((b, 1), np.float32),
                    np.zeros((b, 1), np.int64))

    delay = 0.05
    rt = ServingRuntime(RecordingEngine(),
                        ServingConfig(buckets=(1,), max_wait_s=1e-3,
                                      deadline_s=0.2))
    rt.faults = FaultInjector(FaultPlan(seed=0, rules=(
        FaultRule("engine.straggler", delay_s=delay),)))
    rt.submit(np.zeros(4, np.float32), now=0.0)
    rt.step(now=0.0, drain=True)
    assert seen == [pytest.approx(0.2 - delay)]


# -- load shedding -----------------------------------------------------------

def test_bounded_queue_sheds_behind_straggler(small_index, small_corpus):
    """With a straggler slowing the only replica and queue_bound set,
    a burst is partially rejected with ServiceOverloaded (fast feedback)
    instead of queueing unboundedly; accepted requests still finish."""
    inj = FaultInjector(FaultPlan(seed=0, rules=(
        FaultRule("engine.straggler", rate=1.0, delay_s=0.05),)))
    svc = _build(small_index, replicas=1, queue_bound=2, injector=inj)
    svc.warmup()
    q = np.asarray(small_corpus.queries, np.float32)
    futs, shed = [], 0
    for i in range(24):
        try:
            futs.append(svc.submit_async(q[i % len(q)]))
        except ServiceOverloaded:
            shed += 1
    assert shed > 0 and futs             # some rejected, some accepted
    for fut in futs:
        fut.result(timeout=60.0)
    st = svc.stats()
    assert st["aggregate"]["shed"] == shed
    assert st["faults"]["engine.straggler"]["fires"] > 0
    svc.shutdown()


# -- maintenance thread death ------------------------------------------------

def test_maintenance_death_surfaces_on_next_call(small_index, small_corpus):
    inj = FaultInjector(FaultPlan(seed=0, rules=(
        FaultRule("maintenance.death", count=1),)))
    spec = ServiceSpec(engine="local", nprobe=NPROBE, k=10,
                       buckets=(1, 2, 4), max_wait_s=1e-3, mutable=True)
    svc = AnnService.build(spec,
                           points=np.asarray(small_corpus.points,
                                             np.float32),
                           fault_injector=inj)
    svc.warmup()
    pts = np.asarray(small_corpus.points[:4], np.float32)
    with pytest.raises(RuntimeError, match="maintenance failed") as ei:
        svc.run_maintenance(force=True, wait=True)
    assert isinstance(ei.value.__cause__, InjectedFault)
    assert ei.value.__cause__.site == "maintenance.death"
    # the fault is consumed (count=1): the next cycle succeeds and the
    # mutation API works again
    svc.upsert(np.arange(4) + 10_000, pts)
    out = svc.run_maintenance(force=True, wait=True)
    assert out["ran"]
    svc.shutdown()


# -- chaos end to end --------------------------------------------------------

def test_chaos_e2e_floors():
    """Reduced run of the canonical experiment: availability floor,
    zero corrupt (non-degraded bit-exact vs fault-free), degraded
    flagged, the one corrupted spill cluster healed, and the injector's
    ledger consistent with the plan."""
    from repro.service.chaos import run_chaos
    rep = run_chaos(seed=0, n_queries=120)
    assert rep["availability"] >= 0.95
    assert rep["corrupt_results"] == 0
    assert rep["answered"] + rep["failed"] == rep["submitted"] - rep["shed"]
    fs = rep["fault_stats"]
    assert fs["engine.batch"]["fires"] >= 1
    assert fs["tier.spill_corrupt"]["fires"] == 1
    assert rep["rebuilds"] > 0 or rep["verify"]["rebuilt"]
    assert rep["degraded"] + rep["deadline_missed"] >= 0  # keys present
