"""Live-index mutation: upsert/delete semantics, churn recall parity,
generation maintenance, fleet propagation, and the spec v2 schema.

The headline test is churn parity: after a Zipf-skewed interleaved
upsert/delete/search stream (including a maintenance generation swap),
recall@10 of the mutated index must stay within 0.0035 of an index
rebuilt from scratch over the same final alive set — the live path is
allowed to be approximate (PQ codes encoded against live codebooks,
clusters drifting past the size band between maintenance cycles) but not
meaningfully worse than a full rebuild.
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Index, SearchParams, build_ivfpq, pad_clusters,
                        search_ivfpq)
from repro.data import make_clustered_corpus
from repro.runtime.cache import (HotClusterLUTCache, LRUCache,
                                 OnlineHeatEstimator)
from repro.service import (AnnService, IndexSpec, ServiceSpec,
                           SPEC_VERSION)
from repro.service.spec import (_V2_FIELDS, _V3_FIELDS, _V4_FIELDS,
                                _V5_FIELDS)

NPROBE = 8
K = 10


@pytest.fixture(scope="module")
def churn_corpus():
    # 5000 points: first 4000 are the base index, the tail is the
    # insert pool the churn stream draws from
    return make_clustered_corpus(3, n=5000, d=16, n_queries=32,
                                 n_components=24, k_gt=K)


def _build_mutable(points, seed=0, nlist=32):
    return Index.build(jax.random.PRNGKey(seed), points, nlist=nlist,
                       m=8, cb=64, kmeans_iters=4, pq_iters=4,
                       mutable=True)


def _overlap(retrieved, expected):
    """Mean per-query |retrieved ∩ expected| / k over id sets."""
    retrieved = np.asarray(retrieved)
    expected = np.asarray(expected)
    k = expected.shape[1]
    return float(np.mean([
        len(set(retrieved[q].tolist()) & set(expected[q].tolist())) / k
        for q in range(expected.shape[0])]))


# ---------------------------------------------------------------------------
# Index front door
# ---------------------------------------------------------------------------

def test_static_handle_is_zero_copy(small_index):
    """Wrapping a prebuilt IVFPQIndex must be identity, not a copy —
    engines built from the handle stay bit-exact with engines built from
    the raw index (pinned elsewhere)."""
    h = Index(small_index)
    assert h.ivf is small_index
    assert h.search_view is small_index
    assert not h.mutable
    assert len(h) == small_index.ids.shape[0]
    pc = pad_clusters(small_index)
    np.testing.assert_array_equal(np.asarray(h.clusters.sizes),
                                  np.asarray(pc.sizes))
    with pytest.raises(RuntimeError):
        h.upsert([0], np.zeros((1, small_index.centroids.shape[1])))
    with pytest.raises(RuntimeError):
        h.delete([0])


def test_index_spec_build_front_door(churn_corpus):
    pts = np.asarray(churn_corpus.points[:1000], np.float32)
    spec = IndexSpec(nlist=8, m=8, cb=32, kmeans_iters=3, pq_iters=3)
    h = spec.build(pts)
    assert not h.mutable and len(h) == 1000 and h.nlist == 8
    hm = spec.build(pts, mutable=True)
    assert hm.mutable
    hm.upsert([1000], pts[:1])
    assert 1000 in hm and len(hm) == 1001


def test_mutable_upsert_delete_semantics(churn_corpus):
    pts = np.asarray(churn_corpus.points[:2000], np.float32)
    h = _build_mutable(pts, nlist=16)
    assert h.mutable and len(h) == 2000

    # insert new ids
    info = h.upsert(np.arange(2000, 2010), pts[:10] + 0.5)
    assert info == {"n": 10, "inserted": 10, "replaced": 0,
                    "generation": 0}
    assert len(h) == 2010 and 2005 in h
    np.testing.assert_allclose(h.vector(2005), pts[5] + 0.5)

    # upsert an existing id = replace, not duplicate
    info = h.upsert([5], pts[6:7])
    assert info["replaced"] == 1 and info["inserted"] == 0
    assert len(h) == 2010
    np.testing.assert_allclose(h.vector(5), pts[6])

    # delete returns the number actually removed; unknown ids are no-ops
    assert h.delete([2000, 2001, 999999]) == 2
    assert len(h) == 2008 and 2000 not in h
    assert h.delete([2000]) == 0

    # invalid ids rejected
    with pytest.raises(ValueError):
        h.upsert([-1], pts[:1])
    with pytest.raises(ValueError):
        h.upsert([0, 1], pts[:1])       # length mismatch


# ---------------------------------------------------------------------------
# Churn parity (the acceptance bar: within 0.0035 of a full rebuild)
# ---------------------------------------------------------------------------

def test_churn_recall_parity_vs_rebuild(churn_corpus):
    ds = churn_corpus
    pts = np.asarray(ds.points, np.float32)
    base, pool = pts[:4000], pts[4000:]
    queries = np.asarray(ds.queries, np.float32)
    h = _build_mutable(base, seed=0)

    # Zipf-skewed interleaved churn: inserts draw fresh ids from the
    # pool, deletes prefer low ids (skewed, like hot-key churn), and a
    # maintenance cycle runs mid-stream.
    rng = np.random.default_rng(0)
    next_id = 4000
    live = set(range(4000))
    for step in range(8):
        n_ins = 32
        take = rng.integers(0, pool.shape[0], n_ins)
        ids = np.arange(next_id, next_id + n_ins)
        h.upsert(ids, pool[take])
        live.update(ids.tolist())
        next_id += n_ins
        # Zipf-ish victim choice over the live set
        victims = np.asarray(sorted(live))
        zipf_w = 1.0 / (1.0 + np.arange(victims.shape[0]))
        kill = rng.choice(victims, size=16, replace=False,
                          p=zipf_w / zipf_w.sum())
        h.delete(kill)
        live.difference_update(int(v) for v in kill)
        # search mid-churn must never surface a dead id
        _, i_mid = h.search(queries[:8], nprobe=NPROBE, k=K)
        assert set(np.asarray(i_mid).reshape(-1).tolist()) <= live
        if step == 4:
            h.run_maintenance(force=True, seed=7)

    assert set(int(p) for p in h.live_ids()) == live

    # final alive set, in id order: groundtruth + rebuild baseline
    alive_ids = np.asarray(sorted(live))
    alive_vecs = np.stack([h.vector(int(p)) for p in alive_ids])
    d2 = (np.sum(queries ** 2, 1)[:, None]
          + np.sum(alive_vecs ** 2, 1)[None, :]
          - 2.0 * queries @ alive_vecs.T)
    gt_ids = alive_ids[np.argsort(d2, axis=1)[:, :K]]

    rebuilt = build_ivfpq(jax.random.PRNGKey(0), alive_vecs, nlist=32,
                          m=8, cb=64, kmeans_iters=4, pq_iters=4)
    _, i_reb = search_ivfpq(rebuilt, pad_clusters(rebuilt),
                            jnp.asarray(queries),
                            SearchParams(nprobe=NPROBE, k=K))
    r_rebuild = _overlap(alive_ids[np.asarray(i_reb)], gt_ids)

    _, i_mut = h.search(queries, nprobe=NPROBE, k=K)
    r_mut = _overlap(np.asarray(i_mut), gt_ids)

    assert r_mut >= r_rebuild - 0.0035, \
        f"churned recall {r_mut:.4f} vs rebuild {r_rebuild:.4f}"


def test_tombstones_never_in_results(churn_corpus):
    """Deletes are swap-compacted out of the scanned rows — a dead id
    cannot appear at any nprobe, before or after maintenance."""
    pts = np.asarray(churn_corpus.points[:2000], np.float32)
    queries = np.asarray(churn_corpus.queries, np.float32)
    h = _build_mutable(pts, nlist=16)
    rng = np.random.default_rng(1)
    dead = rng.choice(2000, size=400, replace=False)
    h.delete(dead)
    for nprobe in (1, 8, 16):
        _, ids = h.search(queries, nprobe=nprobe, k=K)
        assert not np.isin(np.asarray(ids), dead).any()
    h.run_maintenance(force=True)
    _, ids = h.search(queries, nprobe=16, k=K)
    assert not np.isin(np.asarray(ids), dead).any()


# ---------------------------------------------------------------------------
# Maintenance: size band, split/merge, generation reconcile
# ---------------------------------------------------------------------------

def test_maintenance_splits_and_merges(churn_corpus):
    pts = np.asarray(churn_corpus.points[:3000], np.float32)
    h = _build_mutable(pts, nlist=16)
    lo, hi = h.size_band()
    assert 1 <= lo < hi

    # force an oversized cluster: pile a tight blob onto one centroid.
    # The auto band scales with total n (hi tracks 4x the mean size),
    # so pin an explicit band the blown-up cluster clearly exceeds.
    c0 = np.asarray(h.centroids)[0]
    blob = c0[None, :] + np.random.default_rng(2).normal(
        0, 1e-3, (600, pts.shape[1])).astype(np.float32)
    h.upsert(np.arange(3000, 3600), blob)
    band = (1, 400)
    plan = h.maintenance_plan(band)
    assert plan["split"], plan
    out = h.run_maintenance(band)
    assert out["ran"] and out["splits"] >= 1
    assert h.generation == 1
    # everything is still findable after the swap
    _, ids = h.search(np.asarray(churn_corpus.queries, np.float32),
                      nprobe=NPROBE, k=K)
    assert np.asarray(ids).min() >= 0


def test_generation_reconciles_concurrent_mutations(churn_corpus):
    """Mutations that land between the maintenance snapshot and the
    install must survive the swap (reconcile path)."""
    pts = np.asarray(churn_corpus.points[:2000], np.float32)
    h = _build_mutable(pts, nlist=16)
    gen = h.build_generation(seed=3)          # snapshot taken here
    late_ids = np.arange(2000, 2016)
    h.upsert(late_ids, pts[:16] + 0.25)       # after the snapshot
    h.delete(np.arange(100, 110))
    info = h.install_generation(gen)
    assert info["reconciled_upserts"] >= 1
    assert info["reconciled_deletes"] >= 1
    assert all(int(p) in h for p in late_ids)
    assert 105 not in h
    _, ids = h.search(pts[:16] + 0.25, nprobe=NPROBE, k=K)
    hit = np.mean([late_ids[q] in np.asarray(ids)[q]
                   for q in range(16)])
    assert hit >= 0.9


# ---------------------------------------------------------------------------
# Service tier: fleet propagation, futures across a swap, sharded engine
# ---------------------------------------------------------------------------

def _mutable_service(points, *, engine="local", replicas=2, **kw):
    spec = ServiceSpec(
        index=IndexSpec(nlist=16, m=8, cb=32, kmeans_iters=4, pq_iters=4),
        engine=engine, replicas=replicas, nprobe=NPROBE, k=K,
        mutable=True, buckets=(1, 2, 4, 8), max_wait_s=1e-3, **kw)
    return AnnService.build(spec, points=points)


def test_service_mutations_replicate_local(churn_corpus):
    pts = np.asarray(churn_corpus.points[:2000], np.float32)
    svc = _mutable_service(pts, replicas=2)
    try:
        new_ids = np.arange(2000, 2032)
        svc.upsert(new_ids, pts[:32] + 0.01)
        # route enough queries that both replicas serve some
        _, ids = svc.search(pts[:32] + 0.01)
        assert _overlap(ids, new_ids[:, None]) >= 0.9
        svc.delete(new_ids[:16])
        _, ids = svc.search(pts[:32] + 0.01)
        assert not np.isin(np.asarray(ids), new_ids[:16]).any()
        out = svc.run_maintenance(force=True)
        assert out["ran"]
        _, ids = svc.search(pts[:32] + 0.01)
        assert not np.isin(np.asarray(ids), new_ids[:16]).any()
        st = svc.stats()["mutation"]
        assert st["upserts"] == 32 and st["deletes"] == 16
        assert st["generation"] == 1 and st["maintenance_runs"] == 1
    finally:
        svc.shutdown()


def test_service_requires_mutable_flag(churn_corpus):
    pts = np.asarray(churn_corpus.points[:1000], np.float32)
    spec = ServiceSpec(
        index=IndexSpec(nlist=8, m=8, cb=32, kmeans_iters=3, pq_iters=3),
        engine="local", replicas=1, nprobe=4, k=5)
    svc = AnnService.build(spec, points=pts)
    try:
        with pytest.raises(RuntimeError, match="mutable"):
            svc.upsert([1000], pts[:1])
        with pytest.raises(RuntimeError, match="mutable"):
            svc.delete([0])
        with pytest.raises(RuntimeError, match="mutable"):
            svc.run_maintenance()
    finally:
        svc.shutdown()


def test_maintenance_swap_preserves_inflight_futures(churn_corpus):
    """Futures submitted before a forced generation swap must all
    resolve — the swap never blocks or drops the serving path."""
    pts = np.asarray(churn_corpus.points[:2000], np.float32)
    queries = np.asarray(churn_corpus.queries, np.float32)
    svc = _mutable_service(pts, replicas=2)
    try:
        svc.warmup()
        futs = [svc.submit_async(queries[q % len(queries)])
                for q in range(24)]
        out = svc.run_maintenance(force=True, wait=True)
        assert out["ran"]
        live = set(int(p) for p in svc.index.live_ids())
        for f in futs:
            d, i = f.result(timeout=30.0)
            assert i.shape == (K,) and np.isfinite(d).all()
            assert set(int(p) for p in i) <= live
    finally:
        svc.shutdown()


def test_sharded_service_mutation(churn_corpus):
    pts = np.asarray(churn_corpus.points[:2000], np.float32)
    svc = _mutable_service(pts, engine="sharded", replicas=1, n_shards=4)
    try:
        gens0 = svc.core_engine().serving_info()["generations"]
        new_ids = np.arange(2000, 2032)
        svc.upsert(new_ids, pts[:32] + 0.01)
        _, ids = svc.search(pts[:32] + 0.01)
        assert _overlap(ids, new_ids[:, None]) >= 0.9
        svc.delete(new_ids[:16])
        _, ids = svc.search(pts[:32] + 0.01)
        assert not np.isin(np.asarray(ids), new_ids[:16]).any()
        out = svc.run_maintenance(force=True)
        assert out["ran"]
        _, ids = svc.search(np.asarray(churn_corpus.queries, np.float32))
        assert np.asarray(ids).min() >= 0
        # staged installs happen at batch starts on the serving path
        assert svc.core_engine().serving_info()["generations"] > gens0
    finally:
        svc.shutdown()


# ---------------------------------------------------------------------------
# Per-generation invalidation primitives
# ---------------------------------------------------------------------------

def test_lru_cache_clear_counts():
    c = LRUCache(capacity=4)
    c.put("a", np.zeros(4, np.float32))
    c.put("b", np.zeros(4, np.float32))
    assert c.stats.entries == 2
    c.clear()
    assert c.stats.entries == 0 and c.stats.bytes == 0
    assert c.stats.clears == 1
    assert c.get("a") is None
    wrapped = HotClusterLUTCache(capacity=4)
    wrapped.put_by_bucket(3, 7, np.zeros((4, 4), np.float32))
    wrapped.clear()
    assert wrapped.stats.entries == 0
    assert wrapped.stats.clears == 1


def test_heat_estimator_reset_resizes():
    est = OnlineHeatEstimator(8, halflife_batches=4.0)
    est.observe(np.array([[0, 1, 2]]))
    assert est.heat().sum() > 0
    est.reset(nlist=12)
    assert est.nlist == 12 and est.heat().shape == (12,)
    assert est.heat().sum() == 0 and est.batches_observed == 0
    seed = np.full(12, 0.5)
    est.reset(nlist=12, seed=seed)
    assert est.heat().shape == (12,) and est.heat().sum() > 0


@pytest.mark.filterwarnings("ignore::DeprecationWarning")
def test_local_engine_view_generation(small_index, small_clusters):
    from repro.runtime import LocalEngine
    eng = LocalEngine(small_index, small_clusters,
                      SearchParams(nprobe=4, k=5))
    g0 = eng.view_generation
    eng.install(clusters=small_clusters)     # data-only: same generation
    assert eng.view_generation == g0
    eng.install(index=small_index, clusters=small_clusters)
    assert eng.view_generation == g0 + 1     # codebook/centroids changed


# ---------------------------------------------------------------------------
# Spec schema v2
# ---------------------------------------------------------------------------

def test_spec_v2_roundtrip():
    spec = ServiceSpec(mutable=True, mutation_size_band=(4, 4000),
                       mutation_maintenance_interval=64,
                       mutation_compact_threshold=0.25)
    d = spec.to_dict()
    assert d["version"] == SPEC_VERSION >= 2
    assert d["mutation_size_band"] == [4, 4000]
    assert ServiceSpec.from_dict(d) == spec


def test_spec_v1_files_still_load():
    """A v1 deploy file (no mutation or storage keys) loads with both off."""
    d = ServiceSpec().to_dict()
    d["version"] = 1
    for key in (_V2_FIELDS | _V3_FIELDS | _V4_FIELDS | _V5_FIELDS):
        d.pop(key)
    spec = ServiceSpec.from_dict(d)
    assert not spec.mutable
    assert spec.mutation_size_band == (0, 0)


def test_spec_v1_with_v2_keys_rejected():
    d = ServiceSpec(mutable=True).to_dict()
    d["version"] = 1
    with pytest.raises(ValueError, match="mutable"):
        ServiceSpec.from_dict(d)


def test_spec_mutation_validation():
    with pytest.raises(ValueError, match="mutation_size_band"):
        ServiceSpec(mutation_size_band=(5, 2)).validate()
    with pytest.raises(ValueError, match="mutable"):
        ServiceSpec(mutation_size_band=(2, 50)).validate()
    with pytest.raises(ValueError, match="mutable"):
        ServiceSpec(mutation_maintenance_interval=8).validate()
    with pytest.raises(ValueError, match="mutation_compact_threshold"):
        ServiceSpec(mutable=True,
                    mutation_compact_threshold=0.0).validate()
    # well-formed mutable spec passes
    ServiceSpec(mutable=True, mutation_size_band=(2, 50),
                mutation_maintenance_interval=8).validate()


def test_spec_v2_save_load(tmp_path):
    spec = ServiceSpec(mutable=True, mutation_maintenance_interval=32)
    p = tmp_path / "deploy.json"
    spec.save(p)
    assert ServiceSpec.load(p) == spec
