"""Tiered storage tests: heat-driven RAM/disk residency for
beyond-memory indexes.

Groups:
  * mmap round-trip — spill + reopen is bit-exact vs PaddedClusters;
  * residency invariance — promote/demote cycles never change neighbor
    sets, at every nprobe (the scan mask, not residency, decides
    results);
  * budget — resident bytes never exceed the configured budget under a
    Zipf-skewed churn stream;
  * damage — TieredStore.open against a vandalized spill dir (truncated
    payloads, missing/mismatched meta.json, flipped bytes): every case
    fails loudly by name, never serves silently-wrong bytes;
  * spec schema — round-trip at the current version, v1-v3 migration,
    and by-name rejection of old-stamped files carrying newer keys;
  * perf model — cold probes are priced strictly above hot probes.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SearchParams, pad_clusters, search_ivfpq
from repro.core.coarse2 import build_coarse2, coarse2_locate
from repro.core.perf_model import (DiskProfile, IndexParams, NVME_PROFILE,
                                   cold_probe_seconds, serving_batch_latency)
from repro.core.search import cluster_locate
from repro.runtime.serving import LocalEngine
from repro.service.spec import (SPEC_VERSION, ServiceSpec, _V4_FIELDS,
                               _V5_FIELDS)
from repro.storage import CorruptClusterError, TieredStore, TieredStoreError


# -- mmap round-trip ---------------------------------------------------------

def test_spill_roundtrip_bit_exact(small_index, small_clusters,
                                   tmp_path_factory):
    """Spilled codes/ids re-read through the tier equal the in-RAM
    padded tensors byte for byte — for fully-cold and fully-hot tiers."""
    ref_codes = np.asarray(small_clusters.codes)
    ref_ids = np.asarray(small_clusters.ids)
    ref_sizes = np.asarray(small_clusters.sizes)
    for tag, budget in (("cold", 1), ("hot", 1 << 30)):
        d = tmp_path_factory.mktemp(f"tier_{tag}")
        tier = TieredStore.from_index(small_index, d, budget_bytes=budget)
        all_c = np.arange(small_index.nlist)[None, :]
        codes, ids, sizes = tier.gather(all_c.ravel())
        np.testing.assert_array_equal(codes, ref_codes)
        np.testing.assert_array_equal(ids, ref_ids)
        np.testing.assert_array_equal(sizes, ref_sizes)


def test_open_rereads_spill(small_index, tmp_path):
    """TieredStore.open on an existing spill dir serves the same bytes
    (a restart does not need the original index object)."""
    t1 = TieredStore.from_index(small_index, tmp_path, budget_bytes=1)
    c1, i1, s1 = t1.gather(np.arange(small_index.nlist))
    t2 = TieredStore.open(tmp_path, budget_bytes=1 << 30)
    c2, i2, s2 = t2.gather(np.arange(small_index.nlist))
    np.testing.assert_array_equal(c1, c2)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(s1, s2)


# -- residency invariance ----------------------------------------------------

@pytest.mark.parametrize("nprobe", [1, 4, 16])
def test_neighbors_invariant_under_residency(small_index, small_clusters,
                                             small_corpus, tmp_path_factory,
                                             nprobe):
    """The tiered engine's neighbor sets equal the all-resident
    pipeline's at every nprobe, for any residency fraction, before and
    after promote/demote churn."""
    p = SearchParams(nprobe=nprobe, k=10)
    sd, si = search_ivfpq(small_index, small_clusters,
                          small_corpus.queries, p)
    sd, si = np.asarray(sd), np.asarray(si)
    d = tmp_path_factory.mktemp(f"tier_np{nprobe}")
    tier = TieredStore.from_index(
        small_index, d,
        budget_bytes=16 * 1)  # tiny: a handful of clusters at most
    tier2 = TieredStore.from_index(
        small_index, tmp_path_factory.mktemp(f"tier2_np{nprobe}"),
        budget_bytes=tier.bytes_per_cluster * 13)
    for t in (tier, tier2):
        eng = LocalEngine(small_index, None, p, tiered_store=t)
        td, ti = eng.search_batch(np.asarray(small_corpus.queries,
                                             np.float32))
        np.testing.assert_array_equal(ti, si)
        np.testing.assert_allclose(td, sd, rtol=1e-5, atol=1e-4)
        for _ in range(3):   # churn heat -> promotes/demotes
            eng.search_batch(np.asarray(small_corpus.queries, np.float32))
        td2, ti2 = eng.search_batch(np.asarray(small_corpus.queries,
                                               np.float32))
        np.testing.assert_array_equal(ti2, si)


def test_explicit_promote_demote_roundtrip(small_index, small_clusters,
                                           tmp_path):
    """Promote then demote a cluster; its bytes after the round trip are
    the original spill bytes (residency is a pure copy, never a move)."""
    probe = TieredStore.from_index(small_index, tmp_path, budget_bytes=1)
    tier2 = TieredStore.from_index(small_index, str(tmp_path) + "_b",
                                   budget_bytes=probe.bytes_per_cluster * 4)
    c = int(np.argmax(np.asarray(small_clusters.sizes)))
    before = tier2.gather(np.array([c]))
    tier2.promote(c)
    assert bool(tier2.resident_mask[c])
    mid = tier2.gather(np.array([c]))
    tier2.demote(c)
    assert not bool(tier2.resident_mask[c])
    after = tier2.gather(np.array([c]))
    for a, b in zip(before, mid):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, b)


# -- budget under churn ------------------------------------------------------

def test_budget_never_exceeded_under_zipf(small_index, small_corpus,
                                          tmp_path):
    """Serve a Zipf-skewed stream through a tier 4x+ smaller than the
    index; after every batch resident_bytes <= budget_bytes."""
    tier = TieredStore.from_index(small_index, tmp_path, budget_bytes=1)
    budget = tier.total_bytes // 5
    tier = TieredStore.from_index(small_index, str(tmp_path) + "_z",
                                  budget_bytes=budget)
    assert tier.total_bytes >= 4 * tier.budget_bytes
    p = SearchParams(nprobe=8, k=10)
    eng = LocalEngine(small_index, None, p, tiered_store=tier)
    rng = np.random.default_rng(0)
    queries = np.asarray(small_corpus.queries, np.float32)
    # zipf-ranked query pool: low indexes drawn much more often
    ranks = rng.zipf(1.3, size=512).clip(1, len(queries)) - 1
    for batch in np.array_split(ranks, 16):
        eng.search_batch(queries[batch])
        assert tier.resident_bytes <= tier.budget_bytes
    st = tier.stats
    assert st.promotions >= 1          # the hot head got promoted
    assert st.hot_hits > 0 and st.cold_fetches > 0
    assert 0.0 < st.hot_rate < 1.0


def test_heat_estimator_drives_promotion(tmp_path, small_index):
    """Clusters probed repeatedly become resident; unprobed ones do
    not displace them (promote margin hysteresis)."""
    tier = TieredStore.from_index(small_index, tmp_path, budget_bytes=1)
    tier = TieredStore.from_index(
        small_index, str(tmp_path) + "_h",
        budget_bytes=tier.bytes_per_cluster * 2)
    hot = np.array([[3, 5]] * 8)
    for _ in range(6):
        tier.observe(hot)
    assert bool(tier.resident_mask[3]) and bool(tier.resident_mask[5])
    tier.observe(np.array([[7, 9]]))   # one lukewarm batch: no displace
    assert bool(tier.resident_mask[3]) and bool(tier.resident_mask[5])


# -- damage: TieredStore.open must fail loudly, never serve bad bytes -------

def _spilled_dir(index, tmp_path):
    """Write a full spill dir (budget=1 keeps every cluster cold) and
    return its path; the TieredStore object itself is discarded."""
    TieredStore.from_index(index, tmp_path, budget_bytes=1)
    return tmp_path


def test_open_rejects_truncated_codes(small_index, tmp_path):
    d = _spilled_dir(small_index, tmp_path)
    f = d / "codes.u8"
    f.write_bytes(f.read_bytes()[:-7])
    with pytest.raises(TieredStoreError, match="truncated"):
        TieredStore.open(d, budget_bytes=1)


def test_open_rejects_truncated_ids(small_index, tmp_path):
    d = _spilled_dir(small_index, tmp_path)
    f = d / "ids.i32"
    f.write_bytes(f.read_bytes()[:-4])
    with pytest.raises(TieredStoreError, match="truncated"):
        TieredStore.open(d, budget_bytes=1)


def test_open_rejects_missing_meta(small_index, tmp_path):
    d = _spilled_dir(small_index, tmp_path)
    (d / "meta.json").unlink()
    with pytest.raises(TieredStoreError, match="missing"):
        TieredStore.open(d, budget_bytes=1)


def test_open_rejects_meta_shape_mismatch(small_index, tmp_path):
    """meta.json claiming a different cluster count than its own sizes
    list (or than the payload files) is caught before any mmap."""
    d = _spilled_dir(small_index, tmp_path)
    meta = json.loads((d / "meta.json").read_text())
    shape = list(meta["codes_shape"])
    shape[0] += 1                       # one phantom cluster
    meta["codes_shape"] = shape
    (d / "meta.json").write_text(json.dumps(meta))
    with pytest.raises(TieredStoreError, match="clusters"):
        TieredStore.open(d, budget_bytes=1)


def test_open_rejects_flipped_payload_byte(small_index, tmp_path):
    """A single flipped byte inside one cluster's codes region fails
    the CRC pass with that cluster's id — sizes all match, so only the
    checksum can catch this."""
    d = _spilled_dir(small_index, tmp_path)
    cap = int(json.loads((d / "meta.json").read_text())["codes_shape"][1])
    m = int(json.loads((d / "meta.json").read_text())["codes_shape"][2])
    target = 2
    raw = bytearray((d / "codes.u8").read_bytes())
    raw[target * cap * m + 3] ^= 0xFF
    (d / "codes.u8").write_bytes(bytes(raw))
    with pytest.raises(CorruptClusterError) as ei:
        TieredStore.open(d, budget_bytes=1)
    assert ei.value.cluster == target
    # with checksums off the same dir opens (sizes are consistent) —
    # the verification is the checksum pass, not a side effect of mmap
    TieredStore.open(d, budget_bytes=1, checksum=False)


def test_corrupt_spill_quarantine_and_rebuild(small_index, tmp_path):
    """In-process heal path: corrupt a resident cluster's spill bytes;
    the cold-fetch CRC catches it, verify(repair=True) rebuilds it from
    the RAM copy, and the tier serves the original bytes again."""
    probe = TieredStore.from_index(small_index, tmp_path, budget_bytes=1)
    tier = TieredStore.from_index(
        small_index, str(tmp_path) + "_r",
        budget_bytes=probe.bytes_per_cluster * 4)
    res = np.nonzero(tier.resident_mask)[0]
    if res.size:                        # slab pre-filled at build time
        c = int(res[0])
    else:
        c = 1
        assert tier.promote(c)
    want = tier.gather(np.array([c]))
    tier.corrupt_spill(c)
    rep = tier.verify(repair=True)
    assert c in rep["corrupt"] and c in rep["rebuilt"]
    assert not rep["quarantined"]
    tier.demote(c)                      # now served from the spill again
    got = tier.gather(np.array([c]))
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a, b)
    assert tier.stats.rebuilds >= 1


def test_corrupt_cold_cluster_quarantined(small_index, tmp_path):
    """No resident copy -> the corrupt cluster is quarantined, named in
    the verify report, and strict gather raises with its id."""
    tier = TieredStore.from_index(small_index, tmp_path, budget_bytes=1)
    c = 3
    tier.corrupt_spill(c)
    rep = tier.verify(repair=True)
    assert c in rep["quarantined"] and not rep["rebuilt"]
    with pytest.raises(CorruptClusterError) as ei:
        tier.gather(np.array([c]))
    assert ei.value.cluster == c


def test_promote_refuses_corrupt_cold_cluster(small_index, tmp_path):
    """Regression: promote() used to load cold bytes into the RAM slab
    unchecked — a corrupted cold cluster promoted by residency churn
    became a trusted hot hit and served rotten bytes as non-degraded
    results.  Promotion must CRC-verify first, quarantine on mismatch,
    and never let the bytes into the slab."""
    probe = TieredStore.from_index(small_index, str(tmp_path) + "_sz",
                                   budget_bytes=1)
    tier = TieredStore.from_index(small_index, tmp_path,
                                  budget_bytes=probe.bytes_per_cluster * 4)
    cold = np.nonzero(~tier.resident_mask)[0]
    assert cold.size, "fixture must leave cold clusters"
    c = int(cold[0])
    tier.corrupt_spill(c)
    fails0 = tier.stats.crc_failures
    assert not tier.promote(c)              # refused, not loaded
    assert tier.stats.crc_failures == fails0 + 1
    assert c in tier.quarantined
    assert not tier.resident_mask[c]
    # degraded gather drops it (sizes==0) instead of serving rotten rows
    codes, ids, sizes, dropped = tier.gather_degraded(np.array([c]))
    assert dropped[0] and sizes[0] == 0
    # and a later promote attempt stays refused via the quarantine
    assert not tier.promote(c)


def test_rewrite_refuses_corrupt_slab_copy(small_index, tmp_path):
    """Regression: the demote-time heal and verify(repair=True) trusted
    the RAM slab unconditionally — a rotten slab copy was rewritten to
    disk and counted as a successful rebuild.  The slab copy must match
    the recorded CRC or the cluster is quarantined, never 'healed'."""
    probe = TieredStore.from_index(small_index, str(tmp_path) + "_sz",
                                   budget_bytes=1)
    tier = TieredStore.from_index(small_index, tmp_path,
                                  budget_bytes=probe.bytes_per_cluster * 4)
    res = np.nonzero(tier.resident_mask)[0]
    assert res.size
    c = int(res[0])
    tier.corrupt_spill(c)                   # spill rotten...
    slot = int(tier._slot_of[c])
    tier._hot_codes[slot][0, 0] ^= 0xFF     # ...and the slab copy too
    rebuilds0 = tier.stats.rebuilds
    rep = tier.verify(repair=True)
    assert c in rep["corrupt"]
    assert c not in rep["rebuilt"]          # no fake heal
    assert c in rep["quarantined"] and c in tier.quarantined
    assert tier.stats.rebuilds == rebuilds0
    # the rotten resident copy is evicted (hot hits are unchecked, so
    # it must not stay servable from the slab)...
    assert not tier.resident_mask[c]
    # ...and the cold path drops it instead of serving rotten bytes
    codes, ids, sizes, dropped = tier.gather_degraded(np.array([c]))
    assert dropped[0] and sizes[0] == 0

    # demote-time heal hits the same wall: evicts, stays quarantined,
    # still no rebuild counted
    res = np.nonzero(tier.resident_mask)[0]
    c2 = int(res[0])
    tier.corrupt_spill(c2)
    slot2 = int(tier._slot_of[c2])
    tier._hot_codes[slot2][0, 0] ^= 0xFF
    assert tier.demote(c2)
    assert tier.stats.rebuilds == rebuilds0
    assert c2 in tier.quarantined and not tier.resident_mask[c2]
    codes, ids, sizes, dropped = tier.gather_degraded(np.array([c2]))
    assert dropped[0] and sizes[0] == 0


# -- two-level coarse quantizer ---------------------------------------------

def test_coarse2_full_fanout_matches_flat(small_index, small_corpus):
    """nprobe1 == n_groups scores every cluster: probe sets equal flat
    cluster_locate's per query (order may differ on ties)."""
    q = jnp.asarray(np.asarray(small_corpus.queries[:16], np.float32))
    flat, _ = cluster_locate(q, small_index.centroids, 8)
    coarse = build_coarse2(jax.random.PRNGKey(0), small_index.centroids,
                           n_groups=6)
    two, _ = coarse2_locate(coarse, q, nprobe=8, nprobe1=coarse.n_groups)
    for r in range(q.shape[0]):
        assert set(np.asarray(two)[r].tolist()) == \
            set(np.asarray(flat)[r].tolist())


def test_coarse2_members_partition_clusters(small_index):
    coarse = build_coarse2(jax.random.PRNGKey(0), small_index.centroids,
                           n_groups=8)
    members = np.asarray(coarse.members)
    live = members[members >= 0]
    assert sorted(live.tolist()) == list(range(small_index.nlist))


# -- spec schema (storage + fail-operational knobs) --------------------------

def _tiered_spec(**kw):
    kw.setdefault("storage", "tiered")
    kw.setdefault("storage_budget_bytes", 1 << 16)
    return ServiceSpec(**kw)


def test_spec_roundtrip_current_version(tmp_path):
    spec = _tiered_spec(storage_promote_margin=1.5, nprobe=4, k=5)
    path = spec.save(tmp_path / "deploy.json")
    assert ServiceSpec.load(path) == spec
    data = json.loads(path.read_text())
    assert data["version"] == SPEC_VERSION == 5


def test_spec_v2_file_loads(tmp_path):
    """A clean v2 deploy file (no v3/v4 keys) loads; the newer knobs
    default to off."""
    data = ServiceSpec(nprobe=4, k=5).to_dict()
    for key in ("storage", "storage_budget_bytes", "storage_promote_margin",
                "storage_dir", "coarse_groups", "coarse_nprobe1",
                *_V4_FIELDS, *_V5_FIELDS):
        data.pop(key)
    data["version"] = 2
    spec = ServiceSpec.from_dict(data)
    assert spec.storage == "resident" and spec.coarse_groups == 0
    assert spec.deadline_ms == 0.0 and spec.checksum is True


@pytest.mark.parametrize("stamp", [1, 2, 3])
def test_spec_old_stamp_with_newer_keys_rejected(stamp):
    data = _tiered_spec(nprobe=4, k=5).to_dict()
    data["version"] = stamp
    if stamp == 1:   # v1 files may not carry v2 keys either
        for key in ("mutable", "mutation_size_band",
                    "mutation_maintenance_interval",
                    "mutation_compact_threshold"):
            data.pop(key)
    with pytest.raises(ValueError, match="newer-schema keys"):
        ServiceSpec.from_dict(data)


def test_spec_v3_validation():
    with pytest.raises(ValueError, match="storage_budget_bytes"):
        ServiceSpec(storage="tiered").validate()
    with pytest.raises(ValueError, match="storage"):
        ServiceSpec(storage="cloud").validate()
    with pytest.raises(ValueError, match="mutable"):
        _tiered_spec(mutable=True).validate()
    with pytest.raises(ValueError, match="storage_budget_bytes"):
        ServiceSpec(storage_budget_bytes=5).validate()
    with pytest.raises(ValueError, match="promote_margin"):
        _tiered_spec(storage_promote_margin=0.5).validate()
    with pytest.raises(ValueError, match="coarse_nprobe1"):
        ServiceSpec(coarse_nprobe1=2).validate()
    with pytest.raises(ValueError, match="engine='local'"):
        ServiceSpec(coarse_groups=4, engine="sharded").validate()
    _tiered_spec().validate()
    ServiceSpec(coarse_groups=4, coarse_nprobe1=2).validate()


# -- perf model: disk tier ---------------------------------------------------

def test_cold_probe_strictly_dearer_than_hot():
    from repro.core.perf_model import UPMEM_PROFILE
    ix = IndexParams(n_total=100_000, nlist=1024, q=1, d=96, k=10, p=16,
                     m=16, cb=256)
    cold = cold_probe_seconds(ix, NVME_PROFILE)
    assert cold > 0.0
    hot = serving_batch_latency(ix, UPMEM_PROFILE, ranks=4, batch=8)
    mixed = serving_batch_latency(ix, UPMEM_PROFILE, ranks=4, batch=8,
                                  cold_fraction=0.25, disk=NVME_PROFILE)
    assert mixed > hot               # any cold fraction adds latency
    colder = serving_batch_latency(ix, UPMEM_PROFILE, ranks=4, batch=8,
                                   cold_fraction=0.5, disk=NVME_PROFILE)
    assert colder > mixed            # monotone in the cold fraction


def test_cold_fraction_validation():
    from repro.core.perf_model import UPMEM_PROFILE
    ix = IndexParams(n_total=1000, nlist=64, q=1, d=16, k=5, p=4, m=8,
                     cb=256)
    with pytest.raises(ValueError):
        serving_batch_latency(ix, UPMEM_PROFILE, ranks=1, batch=4,
                              cold_fraction=0.5)   # no disk profile
    with pytest.raises(ValueError):
        serving_batch_latency(ix, UPMEM_PROFILE, ranks=1, batch=4,
                              cold_fraction=1.5, disk=NVME_PROFILE)
    slow = DiskProfile("slow", seek_s=1e-3, bw=1e8)
    assert cold_probe_seconds(ix, slow) > cold_probe_seconds(ix,
                                                             NVME_PROFILE)
