import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:      # degrade to a fixed-example sweep
    from _hypothesis_fallback import given, settings, st

from repro.core import topk_smallest, merge_topk, running_topk_update
from repro.core.topk import bitonic_sort, bitonic_merge_sorted


def test_topk_smallest_basic():
    d = jnp.array([5.0, 1.0, 3.0, 2.0, 4.0])
    i = jnp.arange(5, dtype=jnp.int32)
    bd, bi = topk_smallest(d, i, 3)
    np.testing.assert_allclose(np.asarray(bd), [1, 2, 3])
    np.testing.assert_array_equal(np.asarray(bi), [1, 3, 2])


@given(st.integers(0, 2**31 - 1), st.sampled_from([8, 16, 64, 128]))
@settings(max_examples=25, deadline=None)
def test_bitonic_sort_property(seed, n):
    rng = np.random.default_rng(seed)
    d = rng.normal(size=(n,)).astype(np.float32)
    i = np.arange(n, dtype=np.int32)   # positional ids
    sd, si = bitonic_sort(jnp.asarray(d), jnp.asarray(i))
    order = np.argsort(d, kind="stable")
    np.testing.assert_allclose(np.asarray(sd), d[order], rtol=1e-6)
    # ids travel with their values (values unique w.p. 1)
    np.testing.assert_allclose(d[np.asarray(si)], d[order], rtol=1e-6)


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 32]))
@settings(max_examples=25, deadline=None)
def test_bitonic_merge_property(seed, k):
    rng = np.random.default_rng(seed)
    a = np.sort(rng.normal(size=(k,)).astype(np.float32))
    b = np.sort(rng.normal(size=(k,)).astype(np.float32))
    ia = np.arange(k, dtype=np.int32)
    ib = np.arange(k, 2 * k, dtype=np.int32)
    md, mi = bitonic_merge_sorted(jnp.asarray(a), jnp.asarray(ia),
                                  jnp.asarray(b), jnp.asarray(ib))
    ref = np.sort(np.concatenate([a, b]))
    np.testing.assert_allclose(np.asarray(md), ref, rtol=1e-6)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_running_topk_matches_full_sort(seed):
    """Property: folding blocks through running_topk_update == top-k of the
    concatenation (the in-kernel TS invariant)."""
    rng = np.random.default_rng(seed)
    k, nblocks, bs = 16, 5, 64
    blocks_d = rng.normal(size=(nblocks, bs)).astype(np.float32)
    blocks_i = np.arange(nblocks * bs, dtype=np.int32).reshape(nblocks, bs)
    best_d = jnp.full((k,), jnp.inf)
    best_i = jnp.full((k,), -1, jnp.int32)
    for bd, bi in zip(blocks_d, blocks_i):
        best_d, best_i = running_topk_update(best_d, best_i,
                                             jnp.asarray(bd), jnp.asarray(bi))
    ref = np.sort(blocks_d.reshape(-1))[:k]
    np.testing.assert_allclose(np.asarray(best_d), ref, rtol=1e-6)


def test_merge_topk():
    d1 = jnp.array([[1.0, 4.0, 9.0]])
    i1 = jnp.array([[10, 40, 90]], dtype=jnp.int32)
    d2 = jnp.array([[2.0, 3.0, 11.0]])
    i2 = jnp.array([[20, 30, 110]], dtype=jnp.int32)
    md, mi = merge_topk(d1, i1, d2, i2, 4)
    np.testing.assert_allclose(np.asarray(md[0]), [1, 2, 3, 4])
    np.testing.assert_array_equal(np.asarray(mi[0]), [10, 20, 30, 40])
