"""End-to-end auto-tuner tests (core/autotune.py): the emitted spec
meets the declared SLO on a held-out stream, infeasible SLOs raise with
the measured frontier attached, and the pipeline is deterministic given
the seed.

One tuning run over a small clustered corpus is shared across tests
(the run builds real indexes and streams a paced calibration load, so
it is the expensive part — everything else asserts against its
result).  Latency numbers come through the PIM-paced engine, which
charges ``max(model, host_elapsed)``: the winner sits far from the SLO
boundary, so host jitter cannot flip any assertion here, but exact
p50/p99 floats are never compared across runs.
"""

import dataclasses
import functools
import math

import numpy as np
import pytest

from repro.core.autotune import (SLO, AutotuneResult, Candidate,
                                 SLOInfeasible, TuneSpace, autotune,
                                 measure_spec, predicted_latency_ms)
from repro.data import make_clustered_corpus

SEED = 0
SLO_MAIN = SLO(recall_at_k=0.8, p99_ms=50.0, k=10)
SPACE = TuneSpace(m=(4, 8), nprobe=(2, 4, 8), lut_dtype=("uint8", "f32"),
                  buckets=((1, 2, 4, 8),), tasks_per_shard=(1024,),
                  cache_capacity_bytes=(0,))
NLIST = 16
N_CALIB = 32       # of the 48 corpus queries; the rest are held out


@functools.lru_cache(maxsize=1)
def _corpus():
    ds = make_clustered_corpus(SEED, n=3000, d=16, n_queries=48,
                               n_components=12, k_gt=10)
    points = np.asarray(ds.points)
    queries = np.asarray(ds.queries, np.float32)
    gt = np.asarray(ds.groundtruth)
    return points, queries, gt


def _tune(seed=SEED, slo=SLO_MAIN, validate_budget=6):
    points, queries, gt = _corpus()
    return autotune(points, slo, queries=queries[:N_CALIB],
                    groundtruth=gt[:N_CALIB], space=SPACE, nlist=NLIST,
                    calibration_requests=48, validate_budget=validate_budget,
                    seed=seed)


@functools.lru_cache(maxsize=1)
def _tuned() -> AutotuneResult:
    return _tune()


def test_emitted_spec_is_validated_and_meets_slo():
    res = _tuned()
    res.spec.validate()                       # deploy-ready artifact
    assert res.slo.met_by(res.measured["recall"], res.measured["p99_ms"])
    assert res.measured["recall"] >= SLO_MAIN.recall_at_k
    assert res.measured["p99_ms"] <= SLO_MAIN.p99_ms
    # bookkeeping is consistent: everything validated is on the
    # frontier, only the last (winning) entry met the SLO
    assert res.validated == len(res.frontier) >= 1
    assert res.modeled == SPACE.size()
    assert 0 <= res.pruned < res.modeled
    assert [e["meets_slo"] for e in res.frontier].count(True) == 1
    assert res.frontier[-1]["meets_slo"]
    assert res.index is not None              # winner's trained index


def test_emitted_spec_meets_slo_on_held_out_stream():
    """The SLO must hold beyond the calibration set: replay a held-out
    query slice (never seen by the tuner) through the emitted spec."""
    res = _tuned()
    points, queries, gt = _corpus()
    held_q, held_gt = queries[N_CALIB:], gt[N_CALIB:]
    assert len(held_q) == 16
    measured = measure_spec(res.spec, res.index, held_q, held_gt,
                            k=SLO_MAIN.k, n_requests=48, qps=4000.0,
                            skew=1.2, seed=SEED + 17)
    assert res.slo.met_by(measured["recall"], measured["p99_ms"]), measured


def test_infeasible_slo_raises_with_frontier():
    impossible = SLO(recall_at_k=0.8, p99_ms=1e-6, k=10)
    with pytest.raises(SLOInfeasible) as ei:
        _tune(slo=impossible, validate_budget=2)
    err = ei.value
    assert err.slo == impossible
    assert len(err.frontier) == 2             # budget exhausted, all shown
    for entry in err.frontier:
        assert not entry["meets_slo"]
        assert entry["p99_ms"] > impossible.p99_ms
        assert {"m", "nprobe", "lut_dtype", "recall", "p99_ms",
                "predicted_ms"} <= set(entry)
    assert "closest" in str(err)              # actionable failure report


def test_autotune_deterministic_given_seed():
    first = _tuned()
    again = _tune()                           # fresh run, same seed
    assert again.spec == first.spec           # identical deploy artifact
    assert again.measured["recall"] == first.measured["recall"]
    assert again.validated == first.validated
    assert ([ (e["m"], e["nprobe"], e["lut_dtype"]) for e in again.frontier]
            == [(e["m"], e["nprobe"], e["lut_dtype"])
                for e in first.frontier])


def test_validation_errors():
    points, queries, gt = _corpus()
    for bad in (SLO(recall_at_k=0.0), SLO(recall_at_k=1.5),
                SLO(p99_ms=0.0), SLO(k=0)):
        with pytest.raises(ValueError):
            bad.validate()
    with pytest.raises(ValueError, match="validate_budget"):
        autotune(points, SLO_MAIN, validate_budget=0)
    with pytest.raises(ValueError, match="non-empty"):
        autotune(points, SLO_MAIN,
                 space=dataclasses.replace(SPACE, nprobe=()))
    with pytest.raises(ValueError, match="unknown dtypes"):
        autotune(points, SLO_MAIN,
                 space=dataclasses.replace(SPACE, lut_dtype=("f16",)))
    # SLO.k deeper than the supplied groundtruth must fail loudly
    with pytest.raises(ValueError, match="recall@10"):
        autotune(points, SLO_MAIN, queries=queries[:8],
                 groundtruth=gt[:8, :5], space=SPACE, nlist=NLIST)


def test_predicted_latency_orders_like_the_knobs():
    """The modeled cost the shortlist sorts on moves the right way with
    each knob (the dominance pruning's soundness rests on this)."""
    base = Candidate(m=8, nprobe=8, lut_dtype="f32",
                     buckets=(1, 2, 4, 8), tasks_per_shard=1024,
                     cache_capacity_bytes=0)
    kw = dict(n_total=100_000, nlist=64, d=32, k=10, ranks=4,
              qps=4000.0, max_wait_s=2e-3)
    t = lambda c: predicted_latency_ms(c, **kw)  # noqa: E731
    assert t(dataclasses.replace(base, nprobe=16)) > t(base)
    assert t(dataclasses.replace(base, m=16)) > t(base)
    assert t(dataclasses.replace(base, lut_dtype="uint8")) < t(base)
    cached = dataclasses.replace(base, cache_capacity_bytes=1 << 20)
    assert t(cached) < t(base)                # hit prior discounts LUTs
    assert math.isfinite(t(base)) and t(base) > 0
