"""Per-architecture smoke tests: reduced same-family config, one forward +
one train-grad step + a few decode steps on CPU; asserts shapes and no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_arch
from repro.models import (init_params, forward, encode, init_caches,
                          decode_step, count_params)

LM_ARCHS = [a for a in ARCH_IDS]


def _inputs(cfg, batch=2, seq=16):
    key = jax.random.PRNGKey(7)
    toks = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    ctx = None
    if cfg.is_encdec:
        ctx = jax.random.normal(key, (batch, cfg.encoder_ctx, cfg.d_model),
                                jnp.float32)
    elif "cross_attn" in cfg.layer_types:
        ctx = jax.random.normal(key, (batch, cfg.vision_ctx, cfg.d_model),
                                jnp.float32)
    return toks, ctx


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = get_config(arch, smoke=True)
    params, axes = init_params(jax.random.PRNGKey(0), cfg)
    assert count_params(params) > 0
    toks, ctx = _inputs(cfg)
    logits, aux = forward(params, cfg, toks, ctx=ctx)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf logits"

    def loss_fn(p):
        lg, aux = forward(p, cfg, toks, ctx=ctx)
        labels = jnp.roll(toks, -1, axis=1)
        ce = -jnp.take_along_axis(jax.nn.log_softmax(lg, -1),
                                  labels[..., None], -1).mean()
        return ce + 0.001 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: NaN loss"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_decode(arch):
    cfg = get_config(arch, smoke=True)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    toks, ctx = _inputs(cfg, seq=8)
    enc_out = encode(params, cfg, ctx) if cfg.is_encdec else None
    caches = init_caches(cfg, batch=2, max_len=8)
    lg = None
    for t in range(8):
        lg, caches = decode_step(params, cfg, toks[:, t:t + 1],
                                 jnp.full((2,), t), caches,
                                 ctx=None if cfg.is_encdec else ctx,
                                 enc_out=enc_out)
    assert lg.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all()), f"{arch}: NaN decode logits"


@pytest.mark.parametrize("arch", ["qwen3_14b", "recurrentgemma_2b",
                                  "mamba2_2p7b", "deepseek_v2_236b",
                                  "whisper_base", "llama32_vision_11b"])
def test_smoke_decode_matches_forward(arch):
    """Causal consistency: step-by-step decode == full forward (no MoE
    capacity drops at these sizes is not guaranteed -> loose tol for MoE)."""
    cfg = get_config(arch, smoke=True)
    import dataclasses as dc
    if cfg.moe:
        cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=8.0))
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    toks, ctx = _inputs(cfg, seq=8)
    enc_out = encode(params, cfg, ctx) if cfg.is_encdec else None
    logits, _ = forward(params, cfg, toks, ctx=ctx)
    caches = init_caches(cfg, batch=2, max_len=8)
    outs = []
    for t in range(8):
        lg, caches = decode_step(params, cfg, toks[:, t:t + 1],
                                 jnp.full((2,), t), caches,
                                 ctx=None if cfg.is_encdec else ctx,
                                 enc_out=enc_out)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    err = float(jnp.max(jnp.abs(dec - logits)))
    assert err < 5e-3, f"{arch}: decode/forward mismatch {err}"


def test_full_configs_construct():
    """Exact assigned shapes parse + param-count sanity (no allocation of
    the big tensors — just config arithmetic)."""
    expected_params = {   # rough published sizes (embedding included), x1e9
        "qwen3_14b": (12, 18), "command_r_plus_104b": (95, 115),
        "phi3_medium_14b": (12, 16), "minitron_4b": (3.5, 5.5),
        "mamba2_2p7b": (2.2, 3.2), "deepseek_v2_236b": (200, 260),
        "recurrentgemma_2b": (2.2, 3.6), "qwen2_moe_a2p7b": (12, 16),
        "whisper_base": (0.04, 0.12), "llama32_vision_11b": (8.5, 11.5),
    }
    from repro.launch.specs import count_params_analytic
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        n = count_params_analytic(cfg) / 1e9
        lo, hi = expected_params[arch]
        assert lo <= n <= hi, f"{arch}: {n:.2f}B params out of [{lo},{hi}]"
