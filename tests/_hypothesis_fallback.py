"""Fixed-example stand-ins for ``hypothesis`` when it isn't installed.

``pip install -e .[test]`` restores the real property sweep; without it,
``given`` runs each property over a small deterministic example grid so
the suite still collects and exercises the code path.
"""

import itertools


class st:
    @staticmethod
    def integers(lo, hi):
        return [lo, (lo + hi) // 2, hi]

    @staticmethod
    def sampled_from(xs):
        return list(xs)


def settings(**_kw):
    return lambda fn: fn


def given(*strategies):
    def deco(fn):
        def wrapper():
            # zip (not product) keeps the fallback cheap; cycle short lists
            n = max(len(s) for s in strategies)
            rows = zip(*(itertools.islice(itertools.cycle(s), n)
                         for s in strategies))
            for row in rows:
                fn(*row)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
