"""Async execution API v2: futures-based request lifecycle, wall-clock
executor-backed streams (results identical to sync search), autoscaling
across grow/shrink events, replica-failure retry, and ServiceSpec
serialization (the durable deploy artifact)."""

import dataclasses
import threading

import numpy as np
import pytest

from repro.runtime.fault_tolerance import ReplicaHealth
from repro.service import (AnnService, Autoscaler, ScaleSignals,
                           SPEC_VERSION, ServiceSpec)

NPROBE = 8


def _build(small_index, **spec_kwargs):
    defaults = dict(engine="local", nprobe=NPROBE, k=10,
                    buckets=(1, 2, 4), max_wait_s=1e-3)
    defaults.update(spec_kwargs)
    return AnnService.build(ServiceSpec(**defaults), index=small_index)


# ---------------------------------------------------------------------------
# Futures: the submit_async lifecycle
# ---------------------------------------------------------------------------

def test_future_result_and_timing(small_index, small_corpus):
    queries = np.asarray(small_corpus.queries[:8], np.float32)
    svc = _build(small_index, replicas=2, router="least_queue")
    svc.warmup()
    direct_d, direct_i = svc.search(queries)
    futs = [svc.submit_async(queries[i]) for i in range(8)]
    for i, fut in enumerate(futs):
        d, ids = fut.result(timeout=30.0)
        assert fut.done()
        np.testing.assert_array_equal(ids, direct_i[i])
        np.testing.assert_allclose(d, direct_d[i], rtol=1e-5)
        t = fut.timing()
        assert set(t) >= {"queue_s", "batch_s", "engine_s", "total_s",
                          "replica", "retried"}
        # the breakdown tiles the total lifecycle
        assert t["total_s"] == pytest.approx(
            t["queue_s"] + t["batch_s"] + t["engine_s"], abs=1e-9)
        assert t["queue_s"] >= 0 and t["engine_s"] > 0
        assert not t["retried"]
        assert t["replica"] in (0, 1)
    svc.shutdown()


def test_future_timeout_fires(small_index, small_corpus):
    """A future on a never-flushed queue times out rather than hanging:
    use the virtual-clock path (no executor workers) so nothing serves."""
    queries = np.asarray(small_corpus.queries[:1], np.float32)
    svc = _build(small_index, replicas=1)
    req = svc.submit(queries[0], now=0.0)          # virtual: nobody steps
    with pytest.raises(TimeoutError, match="not served"):
        req.future.result(timeout=0.05)
    svc.step(now=1.0, drain=True)                  # now it completes
    assert req.future.done()
    svc.shutdown()


def test_sync_submit_is_a_wrapper_over_the_future_lifecycle(small_index,
                                                            small_corpus):
    """The old virtual-clock submit/step surface rides the same request
    lifecycle: the returned Request carries a future that resolves when
    step() serves it, with the same timing breakdown."""
    queries = np.asarray(small_corpus.queries[:4], np.float32)
    svc = _build(small_index, replicas=2, router="round_robin",
                 buckets=(2,), max_wait_s=1e-2)
    svc.warmup()
    reqs = [svc.submit(queries[i], now=0.0) for i in range(4)]
    assert all(r.future is not None and not r.future.done() for r in reqs)
    done = svc.step(now=0.0)
    assert len(done) == 4
    assert all(r.future.done() for r in reqs)
    for r in reqs:
        assert r.timing()["total_s"] >= 0.0
    svc.shutdown()


# ---------------------------------------------------------------------------
# Wall-clock stream == sync search (acceptance)
# ---------------------------------------------------------------------------

def test_wall_stream_matches_sync_search(small_index, small_corpus):
    queries = np.asarray(small_corpus.queries[:16], np.float32)
    svc = _build(small_index, replicas=3, router="cache_aware",
                 cache_capacity=512)
    svc.warmup()
    direct_d, direct_i = svc.search(queries)
    stream = [(i * 1e-3, queries[i % 16]) for i in range(32)]
    reqs = svc.stream(stream, clock="wall")
    assert len(reqs) == 32
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(r.ids, direct_i[i % 16])
        np.testing.assert_allclose(r.dists, direct_d[i % 16], rtol=1e-5)
    st = svc.stats()
    assert st["aggregate"]["requests"] == len(stream)
    assert sum(st["router"]["picks"]) == len(stream)
    svc.shutdown()


def test_wall_and_virtual_streams_agree(small_index, small_corpus):
    """One trace, both drivers: per-query neighbor sets identical."""
    queries = np.asarray(small_corpus.queries[:8], np.float32)
    stream = [(i * 1e-3, queries[i % 8]) for i in range(16)]
    results = {}
    for clock in ("virtual", "wall"):
        svc = _build(small_index, replicas=2, router="round_robin")
        svc.warmup()
        reqs = svc.stream(stream, clock=clock)
        results[clock] = [frozenset(r.ids.tolist()) for r in reqs]
        svc.shutdown()
    assert results["virtual"] == results["wall"]


def test_stream_rejects_unknown_clock(small_index):
    svc = _build(small_index, replicas=1)
    with pytest.raises(ValueError, match="clock"):
        svc.stream([], clock="sundial")
    svc.shutdown()


def test_virtual_apis_refuse_live_executors(small_index, small_corpus):
    """Once executor workers are live they poll the batchers on the wall
    clock; virtual-clock APIs must refuse instead of racing them."""
    queries = np.asarray(small_corpus.queries[:2], np.float32)
    svc = _build(small_index, replicas=1)
    svc.warmup()
    svc.submit_async(queries[0]).result(timeout=30.0)   # workers now live
    with pytest.raises(RuntimeError, match="executor workers are live"):
        svc.stream([(0.0, queries[0])])
    with pytest.raises(RuntimeError, match="executor workers are live"):
        svc.submit(queries[0], now=0.0)
    with pytest.raises(RuntimeError, match="executor workers are live"):
        svc.step(now=1.0)
    # the wall driver still works
    reqs = svc.stream([(0.0, queries[1])], clock="wall")
    assert reqs[0].done
    svc.shutdown()


# ---------------------------------------------------------------------------
# Autoscaling: grow/shrink mid-stream, results invariant (acceptance)
# ---------------------------------------------------------------------------

def test_autoscaler_decision_hysteresis():
    a = Autoscaler(1, 3, queue_high=2.0, queue_low=0.5, cooldown=1)
    assert a.decide(ScaleSignals([5])) == 2        # deep queue: grow
    assert a.decide(ScaleSignals([5, 5])) == 3     # still deep: grow
    assert a.decide(ScaleSignals([5, 5, 5])) == 3  # at max: hold
    assert a.decide(ScaleSignals([1, 1, 1])) == 3  # hysteresis band: hold
    assert a.decide(ScaleSignals([0, 0, 0])) == 2  # idle: shrink
    assert a.decide(ScaleSignals([0, 0])) == 1
    assert a.decide(ScaleSignals([0])) == 1        # at min: hold
    st = a.stats()
    assert st["grows"] == 2 and st["shrinks"] == 2
    assert st["bounds"] == [1, 3]
    # cooldown: back-to-back events are suppressed until it expires
    b = Autoscaler(1, 3, queue_high=2.0, queue_low=0.5, cooldown=3)
    assert b.decide(ScaleSignals([5])) == 2        # first event is armed
    assert b.decide(ScaleSignals([5, 5])) == 2     # cooldown holds...
    assert b.decide(ScaleSignals([5, 5])) == 2
    assert b.decide(ScaleSignals([5, 5])) == 3     # ...then expires


def test_mean_depth_excludes_open_breaker_depths():
    """Regression: mean_depth shrank the denominator by open_breakers
    but kept the open replicas' stale queue depths in the sum, inflating
    the per-serving-replica mean and triggering spurious scale-up on top
    of the explicit lost_capacity grow."""
    # open replica 0 wedged with 9 stale entries; survivors are idle
    s = ScaleSignals([9, 0, 0], open_breakers=1,
                     open_mask=[True, False, False])
    assert s.mean_depth == 0.0           # stale depth fully excluded
    s = ScaleSignals([9, 2, 4], open_breakers=1,
                     open_mask=[True, False, False])
    assert s.mean_depth == 3.0           # mean over serving replicas only
    # all breakers open: no serving replica, depth signal is zero
    assert ScaleSignals([9], open_breakers=1,
                        open_mask=[True]).mean_depth == 0.0
    # legacy count-only callers keep the old shrunken-denominator view
    assert ScaleSignals([9, 0, 0], open_breakers=1).mean_depth == 4.5
    # open breakers still force the lost_capacity grow, but idle
    # survivors must not ALSO read as a deep queue
    a = Autoscaler(1, 3, queue_high=2.0, queue_low=0.5, cooldown=1)
    sig = ScaleSignals([9, 0], open_breakers=1, open_mask=[True, False])
    assert sig.mean_depth < a.queue_high
    assert a.decide(sig) == 3            # grow comes from lost capacity


def test_autoscaler_p99_signal_and_validation():
    a = Autoscaler(1, 2, queue_high=100.0, queue_low=0.01,
                   p99_budget_s=0.010, cooldown=1)
    assert a.decide(ScaleSignals([0], p99_s=0.5)) == 2   # SLO blown: grow
    with pytest.raises(ValueError, match="max_replicas"):
        Autoscaler(3, 2)
    with pytest.raises(ValueError, match="queue_low"):
        Autoscaler(1, 2, queue_high=1.0, queue_low=2.0)


def test_wall_stream_with_autoscale_grow_and_shrink(small_index,
                                                    small_corpus):
    """Burst then trickle: the fleet grows under the burst, shrinks on
    the quiet tail, and every request's neighbors still match the sync
    search — the acceptance invariant across scale events."""
    queries = np.asarray(small_corpus.queries[:16], np.float32)
    # queue_low=0.5: at tick time the just-submitted request is still
    # queued, so an idle 3-replica fleet reads mean depth 1/3
    svc = _build(small_index, replicas=1, replicas_max=3,
                 autoscale_queue_high=1.5, autoscale_queue_low=0.5,
                 autoscale_cooldown=1, autoscale_interval=4,
                 max_wait_s=3e-3)
    svc.warmup()
    direct_d, direct_i = svc.search(queries)
    burst = [(i * 1e-4, queries[i % 16]) for i in range(48)]
    tail_t0 = burst[-1][0]
    tail = [(tail_t0 + 0.03 * (j + 1), queries[j % 16]) for j in range(16)]
    reqs = svc.stream(burst + tail, clock="wall")
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(r.ids, direct_i[i % 16])
        np.testing.assert_allclose(r.dists, direct_d[i % 16], rtol=1e-5)
    st = svc.stats()
    assert st["autoscaler"]["grows"] >= 1, st["autoscaler"]
    assert st["autoscaler"]["shrinks"] >= 1, st["autoscaler"]
    assert sum(st["router"]["picks"]) == len(reqs)
    # live fleet stayed inside the spec bounds throughout
    for ev in st["autoscaler"]["events"]:
        assert 1 <= ev["n_after"] <= 3
    svc.shutdown()


def test_scale_to_bounds_and_router_follow(small_index, small_corpus):
    svc = _build(small_index, replicas=1, replicas_max=3)
    svc.warmup()
    svc._ensure_executors()
    svc.scale_to(5)                                # clamped to max
    assert svc.n_replicas == 3
    assert svc.router.n_replicas == 3
    assert len(svc.replicas) == 3
    svc.scale_to(0)                                # clamped to min
    assert svc.n_replicas == 1
    assert svc.router.n_replicas == 1
    assert len(svc.replicas) == 3                  # parked, not destroyed
    queries = np.asarray(small_corpus.queries[:4], np.float32)
    d, i = svc.search(queries)                     # still serves
    assert i.shape == (4, 10)
    svc.shutdown()


# ---------------------------------------------------------------------------
# PIM-paced serving (hardware-in-the-loop timing model)
# ---------------------------------------------------------------------------

def test_pim_paced_changes_timing_not_results(small_index, small_corpus):
    """pim_paced_ranks paces each batch to its Eq. 15 modeled latency:
    neighbor results stay bit-identical to the unpaced service; served
    engine time is at least the modeled floor."""
    queries = np.asarray(small_corpus.queries[:8], np.float32)
    plain = _build(small_index, replicas=1)
    paced = _build(small_index, replicas=1, pim_paced_ranks=4)
    d0, i0 = plain.search(queries)
    d1, i1 = paced.search(queries)                 # bulk path: unpaced
    np.testing.assert_array_equal(i1, i0)
    np.testing.assert_array_equal(d1, d0)
    paced.warmup()
    engine = paced.replicas[0].runtime.engine
    floor = engine.batch_latency_s(1)              # one-query batch model
    assert floor > 0
    reqs = paced.stream([(i * 1e-3, queries[i]) for i in range(8)],
                        clock="wall")
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(r.ids, i0[i])
        assert r.timing()["engine_s"] >= 0.9 * floor
    assert engine.paced_batches >= 1
    plain.shutdown()
    paced.shutdown()


# ---------------------------------------------------------------------------
# Fault tolerance: a replica failing mid-batch (satellite)
# ---------------------------------------------------------------------------

class _FlakyEngine:
    """Fails the first ``n_failures`` live batches, then recovers."""

    def __init__(self, inner, n_failures=1):
        self.inner = inner
        self.k = inner.k
        self.n_failures = n_failures
        self.calls = 0
        self.lock = threading.Lock()

    def search_batch(self, queries, n_valid=None):
        if n_valid is None or n_valid > 0:      # never fail warmup padding
            with self.lock:
                if self.calls < self.n_failures:
                    self.calls += 1
                    raise RuntimeError("injected PIM rank failure")
        return self.inner.search_batch(queries, n_valid)


def test_replica_failure_retries_on_another(small_index, small_corpus):
    queries = np.asarray(small_corpus.queries[:8], np.float32)
    svc = _build(small_index, replicas=2, router="round_robin",
                 buckets=(1, 2), max_wait_s=1e-3)
    svc.warmup()
    direct_d, direct_i = svc.search(queries)
    rep0 = svc.replicas[0]
    flaky = _FlakyEngine(rep0.engine, n_failures=1)
    rep0.engine = rep0.runtime.engine = flaky
    futs = [svc.submit_async(queries[i]) for i in range(8)]
    for i, fut in enumerate(futs):
        d, ids = fut.result(timeout=30.0)      # failover is invisible
        np.testing.assert_array_equal(ids, direct_i[i])
    st = svc.stats()
    assert st["aggregate"]["retries"] >= 1
    assert st["health"]["failures"][0] >= 1
    assert st["health"]["failures"][1] == 0
    assert st["health"]["unhealthy"] == []     # one failure, then recovery
    retried = [f for f in futs if f.timing()["retried"]]
    assert retried and all(f.timing()["replica"] == 1 for f in retried)
    svc.shutdown()


def test_failure_with_no_retry_target_raises(small_index, small_corpus):
    """Single-replica fleet: nowhere to retry — the future surfaces the
    engine error instead of hanging."""
    queries = np.asarray(small_corpus.queries[:2], np.float32)
    svc = _build(small_index, replicas=1, buckets=(1,), max_wait_s=1e-4)
    svc.warmup()
    rep = svc.replicas[0]
    flaky = _FlakyEngine(rep.engine, n_failures=100)
    rep.engine = rep.runtime.engine = flaky
    fut = svc.submit_async(queries[0])
    with pytest.raises(RuntimeError, match="injected"):
        fut.result(timeout=30.0)
    assert svc.stats()["health"]["failures"][0] >= 1
    svc.shutdown()


def test_replica_health_tracker():
    h = ReplicaHealth(3, max_consecutive=2)
    assert h.healthy() == [0, 1, 2]
    h.record_failure(1)
    assert h.is_healthy(1)
    h.record_failure(1)
    assert not h.is_healthy(1)
    assert h.healthy() == [0, 2]
    h.record_success(1)                            # recovery resets
    assert h.is_healthy(1)
    assert h.stats()["failures"] == [0, 2, 0]
    h.resize(5)
    assert h.healthy() == [0, 1, 2, 3, 4]
    h.resize(2)
    assert h.n_replicas == 2


# ---------------------------------------------------------------------------
# ServiceSpec serialization (acceptance: lossless round-trip)
# ---------------------------------------------------------------------------

def _nondefault_spec():
    return ServiceSpec(engine="sharded", replicas=2, replicas_max=4,
                       router="cache_aware", nprobe=4, k=5,
                       lut_dtype="uint8", n_shards=4, tasks_per_shard=256,
                       relayout_every=8, heat_aware_admission=True,
                       tune_tasks_per_shard=True,
                       engine_overrides={"naive_layout": True},
                       cache_capacity_bytes=1 << 20,
                       buckets=(2, 8), max_wait_s=5e-3,
                       autoscale_p99_budget_ms=12.5)


@pytest.mark.parametrize("spec", [ServiceSpec(), _nondefault_spec()],
                         ids=["default", "nondefault"])
def test_spec_dict_roundtrip_lossless(spec):
    d = spec.to_dict()
    assert d["version"] == SPEC_VERSION
    assert ServiceSpec.from_dict(d) == spec
    # and the dict form is itself stable across a second trip
    assert ServiceSpec.from_dict(d).to_dict() == d


@pytest.mark.parametrize("suffix", [".json", ".yaml"])
def test_spec_file_roundtrip(tmp_path, suffix):
    spec = _nondefault_spec()
    path = spec.save(tmp_path / f"deploy{suffix}")
    assert ServiceSpec.load(path) == spec


def test_spec_from_dict_rejects_unknown_and_versions():
    spec = ServiceSpec()
    with pytest.raises(ValueError, match="unknown keys.*'qs_per_node'"):
        ServiceSpec.from_dict({**spec.to_dict(), "qs_per_node": 3})
    with pytest.raises(ValueError, match="unknown IndexSpec keys"):
        d = spec.to_dict()
        d["index"]["n_list"] = 64
        ServiceSpec.from_dict(d)
    with pytest.raises(ValueError, match="version"):
        ServiceSpec.from_dict({**spec.to_dict(), "version": 99})
    with pytest.raises(ValueError, match="extension"):
        spec.save("deploy.toml")
    # a serialized spec still validates on load
    with pytest.raises(ValueError, match="replicas_max"):
        ServiceSpec.from_dict({**spec.to_dict(), "replicas": 3,
                               "replicas_max": 2})


def test_spec_validation_autoscale_fields():
    ServiceSpec(replicas=2, replicas_max=4).validate()
    ServiceSpec(replicas=2, replicas_max=0).validate()   # off
    with pytest.raises(ValueError, match="autoscale_queue_low"):
        ServiceSpec(autoscale_queue_low=5.0,
                    autoscale_queue_high=1.0).validate()
    with pytest.raises(ValueError, match="autoscale_cooldown"):
        ServiceSpec(autoscale_cooldown=0).validate()


def test_spec_file_boots_fleet(tmp_path, small_index, small_corpus):
    """--spec acceptance: a saved deploy file stands up a working fleet
    whose streamed results match its own sync search."""
    path = ServiceSpec(engine="local", replicas=2, router="least_queue",
                       nprobe=NPROBE, k=10, buckets=(1, 2, 4),
                       max_wait_s=1e-3).save(tmp_path / "deploy.json")
    svc = AnnService.build(ServiceSpec.load(path), index=small_index)
    svc.warmup()
    queries = np.asarray(small_corpus.queries[:8], np.float32)
    direct_d, direct_i = svc.search(queries)
    reqs = svc.stream([(i * 1e-3, queries[i]) for i in range(8)],
                      clock="wall")
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(r.ids, direct_i[i])
    assert svc.n_replicas == 2
    svc.shutdown()
