import dataclasses
import math

import numpy as np
import pytest

from repro.core.perf_model import (IndexParams, UPMEM_PROFILE,
                                   TPU_V5E_PROFILE, lut_width_bytes,
                                   phase_costs, phase_times,
                                   c2io, total_time, make_task_latency_model,
                                   serving_batch_latency,
                                   roofline_terms, dominant_term, PHASES)


BASE = IndexParams(n_total=100_000_000, nlist=2**14, q=10000, d=128,
                   k=10, p=96, m=16, cb=256)


def test_all_phases_present_and_positive():
    costs = phase_costs(BASE, mult_cycles=32.0)
    assert set(costs) == set(PHASES)
    for ph in PHASES:
        assert costs[ph]["ops"] > 0
        assert costs[ph]["bytes"] + costs[ph]["local_bytes"] > 0


def test_multiplierless_reduces_compute_not_below_io():
    """§III-A: the conversion trades multiplies for scratchpad loads —
    ops drop, (local) bytes rise, in LC and CL (the multiply phases)."""
    with_mult = phase_costs(BASE, mult_cycles=32.0, multiplierless=False)
    without = phase_costs(BASE, mult_cycles=32.0, multiplierless=True)
    for ph in ("CL", "LC"):
        assert without[ph]["ops"] < with_mult[ph]["ops"]
        assert without[ph]["local_bytes"] > with_mult[ph]["local_bytes"]
    # DC/TS have no multiplies — unchanged
    for ph in ("DC", "TS"):
        assert without[ph]["ops"] == with_mult[ph]["ops"]


def test_multiplierless_speedup_magnitude_on_upmem():
    """Paper Fig. 10a: LC speedup ~1.93x, end-to-end 1.17-1.40x.  The model
    should put LC speedup in the 1.5-32x band (bounded by the IO wall)."""
    t_mult = phase_times(BASE, UPMEM_PROFILE, multiplierless=False)
    t_less = phase_times(BASE, UPMEM_PROFILE, multiplierless=True)
    speedup_lc = t_mult["LC"] / t_less["LC"]
    assert 1.2 < speedup_lc < 32.0


def test_bottleneck_shifts_dc_to_lc_with_nlist():
    """Paper Fig. 8: with growing nlist, DC share shrinks, LC share grows."""
    import dataclasses
    small = dataclasses.replace(BASE, nlist=2**12)
    large = dataclasses.replace(BASE, nlist=2**16)
    ts = phase_times(small, UPMEM_PROFILE, multiplierless=True)
    tl = phase_times(large, UPMEM_PROFILE, multiplierless=True)
    share_dc_small = ts["DC"] / (ts["DC"] + ts["LC"])
    share_dc_large = tl["DC"] / (tl["DC"] + tl["LC"])
    assert share_dc_large < share_dc_small


def test_compute_scaling_speedup_paper_fig13():
    """Fig. 13: 2x/5x DPU compute -> 4.63x/7.12x vs CPU; internally the
    PIM time itself must improve sublinearly (compute-bound -> IO-bound)."""
    t1 = total_time(BASE, UPMEM_PROFILE, multiplierless=True, compute_scale=1)
    t2 = total_time(BASE, UPMEM_PROFILE, multiplierless=True, compute_scale=2)
    t5 = total_time(BASE, UPMEM_PROFILE, multiplierless=True, compute_scale=5)
    assert t1 > t2 >= t5
    assert t1 / t5 <= 5.0 + 1e-9   # cannot beat linear
    assert t1 / t2 > 1.05          # compute matters (paper's point)


def test_c2io_drops_with_multiplierless():
    a = c2io(BASE, multiplierless=False)
    b = c2io(BASE, multiplierless=True)
    assert b["LC"] <= a["LC"]


def test_task_latency_model_monotone():
    lm = make_task_latency_model(BASE, UPMEM_PROFILE)
    assert lm.l_lut > 0 and lm.l_calc > 0 and lm.l_sort > 0
    assert lm.task_latency(1000) > lm.task_latency(10)


# -- invariants the auto-tuner's pruning leans on --------------------------
# core.autotune prunes candidates the model says are dominated; that is
# only sound if modeled cost is monotone in the quality knobs (more work
# never gets cheaper) and the uint8 LUT path is genuinely priced below
# f32.  Pin those properties here.

def _t(ix):
    return total_time(ix, UPMEM_PROFILE, multiplierless=True)


def test_total_time_monotone_in_nprobe():
    times = [_t(dataclasses.replace(BASE, p=p)) for p in (8, 32, 96, 128)]
    assert all(a <= b + 1e-15 for a, b in zip(times, times[1:]))
    assert times[0] < times[-1]           # and strictly overall


def test_total_time_monotone_in_m():
    times = [_t(dataclasses.replace(BASE, m=m)) for m in (8, 16, 32, 64)]
    assert all(a <= b + 1e-15 for a, b in zip(times, times[1:]))
    assert times[0] < times[-1]


def test_total_time_monotone_in_dataset_size():
    times = [_t(dataclasses.replace(BASE, n_total=n))
             for n in (10**7, 5 * 10**7, 10**8, 4 * 10**8)]
    assert all(a <= b + 1e-15 for a, b in zip(times, times[1:]))
    assert times[0] < times[-1]


def test_uint8_lut_strictly_cheaper_than_f32():
    assert lut_width_bytes("uint8") < lut_width_bytes("f32")
    with pytest.raises(ValueError):
        lut_width_bytes("f16")
    u8 = dataclasses.replace(BASE, b_lut=lut_width_bytes("uint8"))
    f32 = dataclasses.replace(BASE, b_lut=lut_width_bytes("f32"))
    assert _t(u8) < _t(f32)
    assert (serving_batch_latency(u8, UPMEM_PROFILE, ranks=4, batch=16)
            < serving_batch_latency(f32, UPMEM_PROFILE, ranks=4, batch=16))


def test_serving_batch_latency_invariants():
    lat = lambda **kw: serving_batch_latency(  # noqa: E731
        BASE, UPMEM_PROFILE, **{"ranks": 64, "batch": 8, **kw})
    # non-decreasing in batch (wave count is a ceiling, so plateaus ok)
    batches = [lat(batch=b) for b in (1, 2, 8, 32, 128)]
    assert all(a <= b + 1e-15 for a, b in zip(batches, batches[1:]))
    assert batches[0] < batches[-1]
    # non-increasing in ranks — more PIM ranks never slows a batch
    ranks = [lat(ranks=r) for r in (1, 4, 16, 64, 1024)]
    assert all(a >= b - 1e-15 for a, b in zip(ranks, ranks[1:]))
    assert ranks[0] > ranks[-1]
    # LUT cache hits discount the RC+LC term only: strictly faster, but
    # never below the pure scan/sort floor
    assert lat(lut_hit_rate=0.5) < lat()
    model = make_task_latency_model(BASE, UPMEM_PROFILE)
    floor = (-(-(8 * BASE.p) // 64)) * BASE.c * (model.l_calc + model.l_sort)
    assert lat(lut_hit_rate=1.0) >= floor - 1e-15
    for bad in ({"ranks": 0}, {"batch": 0}, {"lut_hit_rate": 1.5},
                {"lut_hit_rate": -0.1}):
        with pytest.raises(ValueError):
            lat(**bad)


def test_roofline_terms_and_dominance():
    terms = roofline_terms(flops=1e15, hbm_bytes=1e12, collective_bytes=1e10,
                           chips=256)
    assert math.isclose(terms["compute_s"], 1e15 / (256 * 197e12))
    assert math.isclose(terms["memory_s"], 1e12 / (256 * 819e9))
    assert dominant_term({"compute_s": 3, "memory_s": 1, "collective_s": 2}) \
        == "compute_s"
