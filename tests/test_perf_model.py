import math

import numpy as np
import pytest

from repro.core.perf_model import (IndexParams, UPMEM_PROFILE,
                                   TPU_V5E_PROFILE, phase_costs, phase_times,
                                   c2io, total_time, make_task_latency_model,
                                   roofline_terms, dominant_term, PHASES)


BASE = IndexParams(n_total=100_000_000, nlist=2**14, q=10000, d=128,
                   k=10, p=96, m=16, cb=256)


def test_all_phases_present_and_positive():
    costs = phase_costs(BASE, mult_cycles=32.0)
    assert set(costs) == set(PHASES)
    for ph in PHASES:
        assert costs[ph]["ops"] > 0
        assert costs[ph]["bytes"] + costs[ph]["local_bytes"] > 0


def test_multiplierless_reduces_compute_not_below_io():
    """§III-A: the conversion trades multiplies for scratchpad loads —
    ops drop, (local) bytes rise, in LC and CL (the multiply phases)."""
    with_mult = phase_costs(BASE, mult_cycles=32.0, multiplierless=False)
    without = phase_costs(BASE, mult_cycles=32.0, multiplierless=True)
    for ph in ("CL", "LC"):
        assert without[ph]["ops"] < with_mult[ph]["ops"]
        assert without[ph]["local_bytes"] > with_mult[ph]["local_bytes"]
    # DC/TS have no multiplies — unchanged
    for ph in ("DC", "TS"):
        assert without[ph]["ops"] == with_mult[ph]["ops"]


def test_multiplierless_speedup_magnitude_on_upmem():
    """Paper Fig. 10a: LC speedup ~1.93x, end-to-end 1.17-1.40x.  The model
    should put LC speedup in the 1.5-32x band (bounded by the IO wall)."""
    t_mult = phase_times(BASE, UPMEM_PROFILE, multiplierless=False)
    t_less = phase_times(BASE, UPMEM_PROFILE, multiplierless=True)
    speedup_lc = t_mult["LC"] / t_less["LC"]
    assert 1.2 < speedup_lc < 32.0


def test_bottleneck_shifts_dc_to_lc_with_nlist():
    """Paper Fig. 8: with growing nlist, DC share shrinks, LC share grows."""
    import dataclasses
    small = dataclasses.replace(BASE, nlist=2**12)
    large = dataclasses.replace(BASE, nlist=2**16)
    ts = phase_times(small, UPMEM_PROFILE, multiplierless=True)
    tl = phase_times(large, UPMEM_PROFILE, multiplierless=True)
    share_dc_small = ts["DC"] / (ts["DC"] + ts["LC"])
    share_dc_large = tl["DC"] / (tl["DC"] + tl["LC"])
    assert share_dc_large < share_dc_small


def test_compute_scaling_speedup_paper_fig13():
    """Fig. 13: 2x/5x DPU compute -> 4.63x/7.12x vs CPU; internally the
    PIM time itself must improve sublinearly (compute-bound -> IO-bound)."""
    t1 = total_time(BASE, UPMEM_PROFILE, multiplierless=True, compute_scale=1)
    t2 = total_time(BASE, UPMEM_PROFILE, multiplierless=True, compute_scale=2)
    t5 = total_time(BASE, UPMEM_PROFILE, multiplierless=True, compute_scale=5)
    assert t1 > t2 >= t5
    assert t1 / t5 <= 5.0 + 1e-9   # cannot beat linear
    assert t1 / t2 > 1.05          # compute matters (paper's point)


def test_c2io_drops_with_multiplierless():
    a = c2io(BASE, multiplierless=False)
    b = c2io(BASE, multiplierless=True)
    assert b["LC"] <= a["LC"]


def test_task_latency_model_monotone():
    lm = make_task_latency_model(BASE, UPMEM_PROFILE)
    assert lm.l_lut > 0 and lm.l_calc > 0 and lm.l_sort > 0
    assert lm.task_latency(1000) > lm.task_latency(10)


def test_roofline_terms_and_dominance():
    terms = roofline_terms(flops=1e15, hbm_bytes=1e12, collective_bytes=1e10,
                           chips=256)
    assert math.isclose(terms["compute_s"], 1e15 / (256 * 197e12))
    assert math.isclose(terms["memory_s"], 1e12 / (256 * 819e9))
    assert dominant_term({"compute_s": 3, "memory_s": 1, "collective_s": 2}) \
        == "compute_s"
