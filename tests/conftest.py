"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the single real CPU device (dry-run sets its own
flags in its own process)."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import build_ivfpq, pad_clusters
from repro.data import make_clustered_corpus


@pytest.fixture(scope="session")
def small_corpus():
    return make_clustered_corpus(0, n=8000, d=32, n_queries=64,
                                 n_components=32, k_gt=10)


@pytest.fixture(scope="session")
def small_index(small_corpus):
    idx = build_ivfpq(jax.random.PRNGKey(0), small_corpus.points,
                      nlist=64, m=16, cb=256, kmeans_iters=6, pq_iters=6)
    return idx


@pytest.fixture(scope="session")
def small_clusters(small_index):
    return pad_clusters(small_index)
