"""Multi-tenant serving properties (PR 10): isolation, predicate
filtering, padding discipline, and the QoS mechanism units.

The load-bearing invariants, asserted bit-exactly:

  * tenant-scoped search over the shared index equals a dedicated
    single-tenant index built from the same rows (same codebook /
    rotation / ids) — across nprobe and both LUT dtypes, and across the
    local, sharded, and tiered engines;
  * predicate-filtered search equals brute-force post-filtering: an
    unfiltered large-k search over the same probes, filtered by the
    host-side reference mask and truncated to k — never the other way
    around (exact filtered top-k, no post-hoc truncation);
  * padding rows (id -1) and out-of-scope rows can never match: a
    tenant with fewer than k rows gets an (inf, -1) tail identical to
    the padding invariant's.

Plus unit tests for the QoS mechanism pieces: TokenBucket refill,
TenantRegistry resolution/shed accounting, WFQScheduler weight-ratio
dispatch order and window bounding, and Router.record pick accounting
for sticky WFQ dispatch.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import SearchParams, pad_clusters, search_ivfpq
from repro.core.filter import (NO_TAG, NO_TENANT, VectorMeta, pad_terms,
                               scope_mask, tenant_subindex)
from repro.service import AnnService, ServiceSpec
from repro.service.router import LeastQueuePolicy, Router
from repro.service.tenancy import TenantRegistry, TokenBucket, WFQScheduler

N_TENANTS = 3
TAG_MOD = 5


def _meta_arrays(n):
    """Per-vector tenants striped over N_TENANTS; one tag column
    cycling mod TAG_MOD (so every tenant holds every tag value)."""
    tenants = (np.arange(n) % N_TENANTS).astype(np.int32)
    tags = (np.arange(n) % TAG_MOD).astype(np.uint32)[:, None]
    return tenants, tags


def _build_service(index, points, nprobe, lut_dtype, **spec_kw):
    n = len(points)
    tenants, tags = _meta_arrays(n)
    spec_kw.setdefault("engine", "local")
    spec = ServiceSpec(replicas=1, nprobe=nprobe, k=10,
                       lut_dtype=lut_dtype, buckets=(1, 2, 4),
                       max_wait_s=1e-3, **spec_kw)
    return AnnService.build(spec, index=index, tenants=tenants, tags=tags,
                            **({"sample_queries": points[:32]}
                               if spec_kw.get("engine") == "sharded" else {}))


def _dedicated_reference(index, meta, tid, queries, nprobe, k, lut_dtype):
    """The isolation oracle: a dedicated single-tenant index from the
    same rows (same codebook/rotation, original global ids)."""
    sub, members = tenant_subindex(index, meta, tid)
    p = min(nprobe, len(members))
    d, i = search_ivfpq(sub, pad_clusters(sub), jnp.asarray(queries),
                        SearchParams(nprobe=p, k=k, lut_dtype=lut_dtype))
    return np.asarray(d), np.asarray(i)


def _assert_same_results(d_got, i_got, d_ref, i_ref):
    np.testing.assert_array_equal(np.asarray(i_got), np.asarray(i_ref))
    d_got = np.where(np.isfinite(d_got), d_got, 0.0)
    d_ref = np.where(np.isfinite(d_ref), d_ref, 0.0)
    np.testing.assert_allclose(d_got, d_ref, rtol=1e-5, atol=1e-5)


# -- isolation: scoped == dedicated single-tenant index ----------------------

@pytest.mark.parametrize("lut_dtype", ["f32", "uint8"])
@pytest.mark.parametrize("nprobe", [1, 4, 16])
def test_scoped_bit_identical_to_dedicated_index(small_corpus, small_index,
                                                 nprobe, lut_dtype):
    """Tenant-scoped search over the shared index returns bit-identical
    neighbor ids (and matching distances) to a dedicated index holding
    only that tenant's rows — at every nprobe and both LUT dtypes."""
    points = np.asarray(small_corpus.points)
    queries = np.asarray(small_corpus.queries, np.float32)
    svc = _build_service(small_index, points, nprobe, lut_dtype)
    try:
        tenants, _ = _meta_arrays(len(points))
        meta = svc.index.meta
        for tid in range(N_TENANTS):
            d_s, i_s = svc.search(queries, tenant=tid)
            live = i_s[i_s >= 0]
            assert live.size and np.all(tenants[live] == tid), \
                f"tenant {tid} result leaks another tenant's rows"
            d_ref, i_ref = _dedicated_reference(
                small_index, meta, tid, queries, nprobe, 10, lut_dtype)
            _assert_same_results(d_s, i_s, d_ref, i_ref)
    finally:
        svc.shutdown()


@pytest.mark.parametrize("engine_kw", [
    {"engine": "local"},
    {"engine": "sharded", "n_shards": 4},
    {"engine": "local", "storage": "tiered",
     "storage_budget_bytes": 1 << 16},
], ids=["local", "sharded", "tiered"])
def test_isolation_holds_across_engines(small_corpus, small_index,
                                        engine_kw, tmp_path):
    """The acceptance invariant end-to-end: the same dedicated-index
    oracle holds for the local, sharded, and tiered engine tiers."""
    points = np.asarray(small_corpus.points)
    queries = np.asarray(small_corpus.queries[:16], np.float32)
    if engine_kw.get("storage") == "tiered":
        engine_kw = dict(engine_kw, storage_dir=str(tmp_path))
    svc = _build_service(small_index, points, 4, "f32", **engine_kw)
    try:
        meta = svc.index.meta
        for tid in range(N_TENANTS):
            d_s, i_s = svc.search(queries, tenant=tid)
            d_ref, i_ref = _dedicated_reference(
                small_index, meta, tid, queries, 4, 10, "f32")
            _assert_same_results(d_s, i_s, d_ref, i_ref)
    finally:
        svc.shutdown()


# -- predicate filtering: exact, never post-hoc truncated --------------------

@pytest.mark.parametrize("lut_dtype", ["f32", "uint8"])
@pytest.mark.parametrize("nprobe", [1, 4, 16])
def test_filtered_equals_brute_force_post_filter(small_corpus, small_index,
                                                 small_clusters, nprobe,
                                                 lut_dtype):
    """Predicate-filtered results are bit-identical to brute force:
    rank ALL candidates of the same probes (k = nprobe * cmax, i.e. the
    whole candidate set), drop rows failing the host-side reference
    mask, truncate to k.  Works because predicates don't change coarse
    ranking and top-k tie order is stable by candidate position."""
    points = np.asarray(small_corpus.points)
    queries = np.asarray(small_corpus.queries, np.float32)
    terms = (1, 3)
    k = 10
    svc = _build_service(small_index, points, nprobe, lut_dtype)
    try:
        meta = svc.index.meta
        d_f, i_f = svc.search(queries, terms=terms)

        k_big = nprobe * small_clusters.cmax        # every candidate row
        d_all, i_all = search_ivfpq(
            small_index, small_clusters, jnp.asarray(queries),
            SearchParams(nprobe=nprobe, k=k_big, lut_dtype=lut_dtype))
        d_all, i_all = np.asarray(d_all), np.asarray(i_all)
        keep = meta.match_host(i_all, terms=terms)
        d_ref = np.full((len(queries), k), np.inf, d_all.dtype)
        i_ref = np.full((len(queries), k), -1, i_all.dtype)
        for qi in range(len(queries)):
            sel = np.flatnonzero(keep[qi])[:k]
            d_ref[qi, :sel.size] = d_all[qi, sel]
            i_ref[qi, :sel.size] = i_all[qi, sel]

        _assert_same_results(d_f, i_f, d_ref, i_ref)
        live = i_f[i_f >= 0]
        assert np.all(meta.match_host(live, terms=terms))
    finally:
        svc.shutdown()


def test_tenant_and_predicate_compose(small_corpus, small_index):
    """Tenant scope AND predicate terms compose (both masks applied):
    every returned row belongs to the tenant and carries a term."""
    points = np.asarray(small_corpus.points)
    queries = np.asarray(small_corpus.queries[:16], np.float32)
    svc = _build_service(small_index, points, 4, "f32")
    try:
        meta = svc.index.meta
        _, i_f = svc.search(queries, tenant=1, terms=(2,))
        live = i_f[i_f >= 0]
        assert live.size
        assert np.all(meta.match_host(live, tenant=1, terms=(2,)))
        # and none of the rows matching only one half of the scope leak
        assert np.all(meta.match_host(live, tenant=1))
        assert np.all(meta.match_host(live, terms=(2,)))
    finally:
        svc.shutdown()


# -- padding discipline ------------------------------------------------------

def test_scarce_tenant_gets_inf_minus_one_tail(small_corpus, small_index):
    """A tenant with fewer than k rows yields exactly those rows, then
    an (inf, -1) tail — identical to the padding invariant; no foreign
    or padding row is ever promoted to fill the deficit."""
    points = np.asarray(small_corpus.points)
    queries = np.asarray(small_corpus.queries[:16], np.float32)
    svc = _build_service(small_index, points, 4, "f32")
    try:
        scarce = np.asarray([5, 17, 29])
        svc.index.meta.set(scarce, tenant=7)     # 3 rows < k=10
        d_s, i_s = svc.search(queries, tenant=7)
        assert set(i_s[i_s >= 0]) <= set(scarce.tolist())
        live_n = (i_s >= 0).sum(axis=1)
        assert live_n.max() <= scarce.size
        # the tail is (inf, -1), rows sorted live-first
        for qi in range(len(queries)):
            n = int(live_n[qi])
            assert np.all(i_s[qi, :n] >= 0)
            assert np.all(i_s[qi, n:] == -1)
            assert np.all(np.isinf(d_s[qi, n:]))
    finally:
        svc.shutdown()


def test_scope_mask_padding_and_oob_rows():
    """Unit check on the jit-side mask: padding rows (id -1) never
    match anything; ids beyond the meta tables (mutated after snapshot)
    are visible only to unscoped, predicate-free queries."""
    meta = VectorMeta(capacity=4, tag_fields=2)
    meta.set([0, 1, 2, 3], tenant=[0, 0, 1, NO_TENANT],
             tags=[[7, NO_TAG]] * 4)
    jt, jg = meta.device_tables()
    row_ids = jnp.asarray([[-1, 0, 2, 9],        # pad, t0, t1, out-of-bounds
                           [-1, 1, 3, 9]], jnp.int32)
    # unscoped, no predicate: everything live is visible (incl. oob)
    m = scope_mask(row_ids, jt, jg,
                   jnp.asarray([NO_TENANT, NO_TENANT], jnp.int32),
                   jnp.asarray(pad_terms([(), ()], 2)))
    np.testing.assert_array_equal(np.asarray(m),
                                  [[False, True, True, True],
                                   [False, True, True, True]])
    # tenant-scoped: padding, foreign, unscoped, and oob rows all drop
    m = scope_mask(row_ids, jt, jg, jnp.asarray([0, 0], jnp.int32),
                   jnp.asarray(pad_terms([(), ()], 2)))
    np.testing.assert_array_equal(np.asarray(m),
                                  [[False, True, False, False],
                                   [False, True, False, False]])
    # predicate: oob rows have no tags, so they drop too
    m = scope_mask(row_ids, jt, jg,
                   jnp.asarray([NO_TENANT, NO_TENANT], jnp.int32),
                   jnp.asarray(pad_terms([(7,), (8,)], 2)))
    np.testing.assert_array_equal(np.asarray(m),
                                  [[False, True, True, False],
                                   [False, False, False, False]])


def test_pad_terms_width_enforced():
    out = pad_terms([(1,), (), (2, 3)], 3)
    assert out.shape == (3, 3) and out.dtype == np.uint32
    np.testing.assert_array_equal(out[1], [NO_TAG] * 3)
    with pytest.raises(ValueError, match="filter_width"):
        pad_terms([(1, 2, 3, 4)], 3)


# -- QoS mechanism units -----------------------------------------------------

def test_token_bucket_refill_and_burst_cap():
    b = TokenBucket(rate_qps=2.0, burst=2)
    assert b.take(0.0) and b.take(0.0)           # burst drains
    assert not b.take(0.0)                       # empty
    assert not b.take(0.4)                       # 0.8 tokens — still < 1
    assert b.take(0.6)                           # 1.2 accrued by now
    # a long idle gap refills to the burst cap, not beyond
    assert not b.take(0.6)
    assert b.take(100.0) and b.take(100.0)
    assert not b.take(100.0)


def test_token_bucket_zero_rate_always_admits():
    b = TokenBucket(rate_qps=0.0, burst=1)
    assert all(b.take(float(t)) for t in range(100))


def test_tenant_registry_resolution_and_shed():
    reg = TenantRegistry((("anna", 0, 4.0, 0.0, 1),
                          ("zoe", 3, 1.0, 2.0, 2)))
    assert reg.resolve(None) == NO_TENANT
    assert reg.resolve("zoe") == 3 and reg.resolve(3) == 3
    assert reg.resolve(42) == 42                 # unregistered ids pass
    with pytest.raises(KeyError, match="unknown tenant"):
        reg.resolve("nobody")
    assert reg.weight_of(0) == 4.0 and reg.weight_of(42) == 1.0
    # anna has no quota; zoe sheds past her burst, refills with time
    assert all(reg.admit(0, 0.0) for _ in range(50))
    assert reg.admit(3, 0.0) and reg.admit(3, 0.0)
    assert not reg.admit(3, 0.0)
    assert reg.admit(3, 1.0)                     # 2 qps: 1s -> 2 tokens
    st = reg.stats()
    assert st["anna"]["shed"] == 0 and st["zoe"]["shed"] == 1
    assert st["zoe"]["rate_qps"] == 2.0


def test_wfq_dispatch_order_follows_weight_ratio():
    """Backlogged tenants dispatch at their weight ratio: with A:B
    weights 1:2 and both queues full, every weight-window of dispatches
    sends two B for each A, per-tenant FIFO preserved."""
    reg = TenantRegistry((("a", 0, 1.0, 0.0, 1), ("b", 1, 2.0, 0.0, 1)))
    wfq = WFQScheduler(reg, window=1)
    order = []
    wfq.submit(NO_TENANT, lambda: order.append("warm"))  # occupy the window
    for j in range(6):
        wfq.submit(0, lambda j=j: order.append(("a", j)))
    for j in range(6):
        wfq.submit(1, lambda j=j: order.append(("b", j)))
    assert order == ["warm"] and wfq.pending == 12
    for _ in range(12):
        wfq.on_complete()
    labels = [t for t, _ in order[1:]]
    assert labels[:6] == ["b", "a", "b", "b", "a", "b"]  # 2:1 interleave
    assert labels.count("a") == labels.count("b") == 6   # all drained
    for t in ("a", "b"):                                 # per-tenant FIFO
        assert [j for tt, j in order[1:] if tt == t] == list(range(6))
    st = wfq.stats()
    assert st["queued"] == 0
    assert st["dispatched"] == {"-1": 1, "a": 6, "b": 6}
    assert st["max_queued"] == 12


def test_wfq_window_bounds_in_flight():
    reg = TenantRegistry()
    wfq = WFQScheduler(reg, window=3)
    n_dispatched = []
    for j in range(10):
        wfq.submit(NO_TENANT, lambda: n_dispatched.append(1))
    assert len(n_dispatched) == 3 and wfq.in_flight == 3
    assert wfq.pending == 7
    wfq.on_complete()
    assert len(n_dispatched) == 4 and wfq.in_flight == 3
    with pytest.raises(ValueError, match="window"):
        WFQScheduler(reg, window=0)


def test_router_record_accounts_sticky_dispatch():
    """Router.record (the sticky WFQ dispatch path) keeps pick counts
    summing to the dispatched request count, per tenant too, without
    feeding the policy an affinity signal."""
    router = Router(LeastQueuePolicy(), 3, depth_fn=lambda r: 0)
    q = np.zeros(4, np.float32)
    r0 = router.route(q, tenant=1)
    router.record(r0, tenant=1)                  # sticky repeat
    router.record((r0 + 1) % 3, tenant=2)
    st = router.stats()
    assert sum(st["picks"]) == 3
    assert sum(st["tenant_picks"][1]) == 2
    assert sum(st["tenant_picks"][2]) == 1
    with pytest.raises(ValueError, match="record"):
        router.record(3)
