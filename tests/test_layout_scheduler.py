import numpy as np
import pytest

from repro.core.layout import (split_clusters, duplicate_hot, allocate_greedy,
                               allocate_naive, build_layout, estimate_heat)
from repro.core.scheduler import schedule_batch, schedule_naive
from repro.core.perf_model import (IndexParams, UPMEM_PROFILE,
                                   make_task_latency_model)


def _skewed_world(seed=0, nlist=64, n_shards=8):
    rng = np.random.default_rng(seed)
    sizes = (rng.pareto(1.2, nlist) * 200 + 20).astype(np.int64)
    # Zipfian probe traffic over clusters
    p = 1.0 / np.arange(1, nlist + 1) ** 1.2
    p /= p.sum()
    probes = rng.choice(nlist, size=(256, 8), p=p).astype(np.int64)
    heat = estimate_heat(probes, nlist)
    lm = make_task_latency_model(
        IndexParams(n_total=int(sizes.sum()), nlist=nlist, q=1, d=32, k=10,
                    p=8, m=8, cb=256), UPMEM_PROFILE)
    return sizes, heat, probes, lm, n_shards


def test_split_conserves_rows_and_heat():
    sizes, heat, *_ = _skewed_world()
    insts = split_clusters(sizes, heat, split_max=100)
    assert all(i.size <= 100 for i in insts)
    got_rows = np.zeros_like(sizes)
    got_heat = np.zeros_like(heat)
    for i in insts:
        got_rows[i.cluster] += i.size
        got_heat[i.cluster] += i.heat
    np.testing.assert_array_equal(got_rows, sizes)
    np.testing.assert_allclose(got_heat, heat, rtol=1e-9)
    # parts are contiguous, disjoint ranges
    for c in range(len(sizes)):
        parts = sorted([i for i in insts if i.cluster == c],
                       key=lambda i: i.part)
        pos = 0
        for p in parts:
            assert p.start == pos
            pos += p.size
        assert pos == sizes[c]


def test_duplicate_respects_budget_and_targets_hot():
    sizes, heat, *_ = _skewed_world()
    insts = split_clusters(sizes, heat, split_max=100)
    budget = 50 * 100 * 32
    dup = duplicate_hot(insts, bytes_per_row=32, dup_budget_bytes=budget)
    extra = sum(i.size for i in dup) - sum(i.size for i in insts)
    assert 0 < extra * 32 <= budget
    # the hottest original cluster got replicated
    hottest = int(np.argmax(heat))
    reps = {}
    for i in dup:
        reps.setdefault((i.cluster, i.part), 0)
        reps[(i.cluster, i.part)] += 1
    assert max(r for (c, p), r in reps.items() if c == hottest) >= 2


def test_greedy_allocation_beats_naive():
    """Paper Fig. 11b: heat-aware allocation alone gives 1.76-4.07x better
    balance than ID-order."""
    sizes, heat, probes, lm, n_shards = _skewed_world()
    insts = split_clusters(sizes, heat, split_max=10**9)   # no split
    naive = allocate_naive(insts, n_shards)
    greedy = allocate_greedy(insts, n_shards, lm)

    def makespan(shard_of):
        loads = np.zeros(n_shards)
        for i in insts:
            loads[shard_of[i.instance_id]] += i.heat * lm.task_latency(i.size)
        return loads.max() / max(loads.mean(), 1e-12)

    assert makespan(greedy) < makespan(naive)
    # without splitting, one hot giant cluster bounds achievable balance
    # (Observation 1) — with splitting the full pipeline gets near-balanced:
    insts_split = split_clusters(sizes, heat, split_max=100)
    greedy_split = allocate_greedy(insts_split, n_shards, lm)
    loads = np.zeros(n_shards)
    for i in insts_split:
        loads[greedy_split[i.instance_id]] += i.heat * lm.task_latency(i.size)
    assert loads.max() / loads.mean() < 1.6


def test_replicas_on_distinct_shards():
    sizes, heat, probes, lm, n_shards = _skewed_world()
    insts = split_clusters(sizes, heat, split_max=100)
    dup = duplicate_hot(insts, bytes_per_row=32,
                        dup_budget_bytes=100 * 100 * 32)
    shard_of = allocate_greedy(dup, n_shards, lm)
    seen = {}
    for i in dup:
        key = (i.cluster, i.part)
        seen.setdefault(key, set())
        assert shard_of[i.instance_id] not in seen[key], \
            "replica landed on the same shard"
        seen[key].add(shard_of[i.instance_id])


def test_full_layout_pipeline_balances():
    sizes, heat, probes, lm, n_shards = _skewed_world()
    lay_naive = build_layout(sizes, heat, n_shards, split_max=10**9,
                             naive=True)
    lay_opt = build_layout(sizes, heat, n_shards, split_max=100,
                           dup_budget_bytes=200 * 100 * 32, bytes_per_row=32,
                           latency=lm)
    assert lay_opt.stats(lm)["imbalance"] < lay_naive.stats(lm)["imbalance"]


def test_schedule_covers_all_tasks_or_defers():
    sizes, heat, probes, lm, n_shards = _skewed_world()
    lay = build_layout(sizes, heat, n_shards, split_max=100,
                       dup_budget_bytes=100 * 100 * 32, latency=lm)
    slot = np.zeros(len(lay.instances), np.int64)
    for s in range(n_shards):
        for j, inst in enumerate(lay.instances_on(s)):
            slot[inst.instance_id] = j
    sched = schedule_batch(probes[:64], lay, lm, slot, tasks_per_shard=2048,
                           enable_filter=False)
    n_parts_of = {}
    for inst in lay.instances:
        n_parts_of[inst.cluster] = inst.n_parts
    expected = sum(n_parts_of[int(c)] for q in range(64) for c in probes[q])
    assert int(sched.n_tasks.sum()) == expected
    assert not sched.deferred
    # every scheduled slot is valid
    for s in range(n_shards):
        nt = sched.n_tasks[s]
        assert (sched.query_idx[s, :nt] >= 0).all()
        assert (sched.query_idx[s, nt:] == -1).all()


def test_scheduler_beats_naive_balance():
    """Paper Fig. 11a: scheduling + layout gives 4.84-6.19x; we assert the
    direction and a >=2x balance gain on a skewed batch."""
    sizes, heat, probes, lm, n_shards = _skewed_world(seed=3)
    lay = build_layout(sizes, heat, n_shards, split_max=100,
                       dup_budget_bytes=300 * 100 * 32, latency=lm)
    slot = np.zeros(len(lay.instances), np.int64)
    for s in range(n_shards):
        for j, inst in enumerate(lay.instances_on(s)):
            slot[inst.instance_id] = j
    opt = schedule_batch(probes[:128], lay, lm, slot, tasks_per_shard=4096,
                         enable_filter=False)
    # naive: same layout without replicas used, no least-load choice
    naive = schedule_naive(probes[:128], lay, lm, slot, tasks_per_shard=4096)
    assert opt.predicted_load.max() < naive.predicted_load.max()
    assert naive.imbalance / opt.imbalance > 1.5


def test_filter_defers_and_carries_over():
    sizes, heat, probes, lm, n_shards = _skewed_world(seed=5)
    lay = build_layout(sizes, heat, n_shards, split_max=100, latency=lm)
    slot = np.zeros(len(lay.instances), np.int64)
    for s in range(n_shards):
        for j, inst in enumerate(lay.instances_on(s)):
            slot[inst.instance_id] = j
    s1 = schedule_batch(probes[:128], lay, lm, slot, tasks_per_shard=4096,
                        filter_ratio=1.05, enable_filter=True)
    assert len(s1.deferred) > 0          # skew forces deferral
    s2 = schedule_batch(probes[128:192], lay, lm, slot, tasks_per_shard=4096,
                        carry_in=s1.deferred, enable_filter=False)
    # carried tasks got scheduled
    total = int(s2.n_tasks.sum())
    n_parts_of = {i.cluster: i.n_parts for i in lay.instances}
    fresh = sum(n_parts_of[int(c)] for q in range(64) for c in probes[128 + q])
    carried = len(s1.deferred)   # each deferred triple is exactly one task
    assert total == fresh + carried
