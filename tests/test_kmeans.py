import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kmeans, kmeans_multi, l2_sq, assign_chunked


def test_l2_sq_matches_numpy():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(17, 9)).astype(np.float32)
    y = rng.normal(size=(5, 9)).astype(np.float32)
    ref = ((x[:, None, :] - y[None, :, :]) ** 2).sum(-1)
    got = np.asarray(l2_sq(jnp.asarray(x), jnp.asarray(y)))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-3)


def test_assign_chunked_matches_full():
    rng = np.random.default_rng(1)
    pts = jnp.asarray(rng.normal(size=(1000, 8)).astype(np.float32))
    cents = jnp.asarray(rng.normal(size=(13, 8)).astype(np.float32))
    a, d = assign_chunked(pts, cents, chunk=128)
    full = l2_sq(pts, cents)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(jnp.argmin(full, 1)))
    np.testing.assert_allclose(np.asarray(d), np.asarray(jnp.min(full, 1)),
                               rtol=1e-4, atol=1e-3)


def test_kmeans_separates_obvious_clusters():
    rng = np.random.default_rng(2)
    centers = np.array([[0.0, 0], [100, 0], [0, 100], [100, 100]])
    pts = np.concatenate([c + rng.normal(0, 1, size=(200, 2)) for c in centers])
    st = kmeans(jax.random.PRNGKey(0), jnp.asarray(pts, jnp.float32), k=4,
                iters=20)
    # every learned centroid is within 2 units of a true center
    d = np.asarray(l2_sq(st.centroids, jnp.asarray(centers, jnp.float32)))
    assert (d.min(axis=1) < 4.0).all()
    assert float(st.obj) < 3.0


def test_kmeans_objective_decreases():
    rng = np.random.default_rng(3)
    pts = jnp.asarray(rng.normal(size=(2000, 16)).astype(np.float32))
    o2 = float(kmeans(jax.random.PRNGKey(1), pts, k=32, iters=2).obj)
    o10 = float(kmeans(jax.random.PRNGKey(1), pts, k=32, iters=10).obj)
    assert o10 <= o2 + 1e-5


def test_kmeans_no_empty_clusters():
    # pathological: k close to n
    rng = np.random.default_rng(4)
    pts = jnp.asarray(rng.normal(size=(64, 4)).astype(np.float32))
    st = kmeans(jax.random.PRNGKey(2), pts, k=32, iters=8, chunk=64)
    counts = np.bincount(np.asarray(st.assign), minlength=32)
    assert (counts > 0).sum() >= 28  # near-full utilization after reseeding


def test_kmeans_multi_shapes():
    rng = np.random.default_rng(5)
    pts = jnp.asarray(rng.normal(size=(4, 500, 6)).astype(np.float32))
    st = kmeans_multi(jax.random.PRNGKey(3), pts, k=16, iters=4)
    assert st.centroids.shape == (4, 16, 6)
    assert st.assign.shape == (4, 500)
    assert np.isfinite(np.asarray(st.obj)).all()
