"""Substrate tests: optimizer, pipeline determinism, checkpoint/restart,
fault tolerance control plane, gradient compression."""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.optim.grad_compress import (compress_int8, decompress_int8,
                                       ef_init, ef_step)
from repro.data.pipeline import make_token_pipeline
from repro.checkpoint import Checkpointer
from repro.runtime import (HeartbeatRegistry, plan_elastic_mesh,
                           StragglerPolicy, RunSupervisor)


# -- optimizer ---------------------------------------------------------------

def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, grad_clip=100.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw.update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adamw_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(adamw.schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[1] == pytest.approx(0.5, abs=1e-6)     # mid-warmup
    assert lrs[2] == pytest.approx(1.0, abs=1e-6)     # peak
    assert lrs[4] == pytest.approx(0.1, abs=1e-2)     # floor


def test_grad_clip_applied():
    cfg = AdamWConfig(lr=1e-9, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = adamw.init(params)
    _, _, m = adamw.update(cfg, {"w": jnp.full(4, 100.0)}, state, params)
    assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-3)


# -- gradient compression ------------------------------------------------------

def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.normal(size=(128,)).astype(np.float32))}
    q, s = compress_int8(g)
    assert q["a"].dtype == jnp.int8
    deq = decompress_int8(q, s)
    err = float(jnp.max(jnp.abs(deq["a"] - g["a"])))
    assert err <= float(s["a"]) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_steps():
    """EF residual keeps the *cumulative* applied gradient close to the
    cumulative true gradient (property of EF-SGD)."""
    rng = np.random.default_rng(1)
    state = ef_init({"w": jnp.zeros(64)})
    total_true = np.zeros(64)
    total_applied = np.zeros(64)
    for i in range(50):
        g = {"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
        applied, state = ef_step(g, state)
        total_true += np.asarray(g["w"])
        total_applied += np.asarray(applied["w"])
    resid = np.abs(total_true - total_applied).max()
    # leftover residual is bounded by one step's quantization error
    assert resid < 0.2


# -- pipeline ------------------------------------------------------------------

def test_pipeline_deterministic_and_seekable():
    p1 = make_token_pipeline(1000, 32, 8, seed=7)
    p2 = make_token_pipeline(1000, 32, 8, seed=7)
    b5a = p1.batch_at(5)
    b5b = p2.batch_at(5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    # labels are tokens shifted by one
    np.testing.assert_array_equal(b5a["tokens"][:, 1:], b5a["labels"][:, :-1])


def test_pipeline_sharding_partitions_batch():
    full = make_token_pipeline(1000, 16, 8, seed=3)
    shards = [make_token_pipeline(1000, 16, 8, seed=3, shard_index=i,
                                  shard_count=4) for i in range(4)]
    got = np.concatenate([s.batch_at(0)["tokens"] for s in shards])
    assert got.shape == full.batch_at(0)["tokens"].shape
    # shards are disjoint parts of the same global batch (same seed/step)
    assert len(np.unique(got.sum(1))) >= 2


# -- checkpoint ----------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    ck.save(10, tree, extra={"step": 10})
    restored, extra = ck.restore(None, tree)
    assert extra["step"] == 10
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_keeps_last_k_and_commit_marker(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    t = {"w": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        ck.save(s, t, extra={"step": s})
    assert ck.all_steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(tmp_path)
    t = {"w": jnp.arange(4.0)}
    ck.save(1, t, extra={"step": 1}, blocking=False)
    ck.wait()
    assert ck.latest_step() == 1


# -- fault tolerance -------------------------------------------------------------

def test_heartbeat_detects_dead_host():
    clock = [0.0]
    reg = HeartbeatRegistry(4, timeout_s=10, clock=lambda: clock[0])
    clock[0] = 5.0
    for h in (0, 1, 3):
        reg.beat(h)
    clock[0] = 12.0
    assert reg.dead() == [2]
    assert sorted(reg.alive()) == [0, 1, 3]


def test_elastic_plan_shrinks_data_axis():
    plan = plan_elastic_mesh(n_alive=13, data_axis=16, model_axis=16)
    assert plan.data_axis == 8 and plan.model_axis == 16


def test_straggler_policy_flags_slow_host():
    clock = [0.0]
    reg = HeartbeatRegistry(4, clock=lambda: clock[0])
    for i in range(10):
        for h in range(4):
            reg.beat(h, step_time_s=1.0 if h != 2 else 3.0)
    assert StragglerPolicy(ratio=1.5).flag(reg) == [2]


def test_supervisor_restart_loop():
    reg = HeartbeatRegistry(16, timeout_s=1e9)
    calls = []

    def run_fn(mesh_shape, start_step):
        calls.append((mesh_shape, start_step))
        if len(calls) == 1:
            return "failed", 40       # crash at step 40 on the full mesh
        return "done", 100

    sup = RunSupervisor(data_axis=16, model_axis=16)
    last = sup.supervise(run_fn, reg)
    assert last == 100
    assert calls[0] == ((16, 16), 0)
    assert calls[1][1] == 40          # resumed from failure step


# -- end-to-end train loop with restart ------------------------------------------

def test_train_restart_resumes_from_checkpoint(tmp_path):
    from repro.configs import get_config
    from repro.launch.train import train_loop
    cfg = get_config("qwen3_14b", smoke=True)
    # run 1: crash at step 6 (ckpt every 3)
    with pytest.raises(RuntimeError):
        train_loop(cfg, steps=10, global_batch=4, seq_len=16,
                   ckpt_dir=tmp_path, ckpt_every=3, fail_at_step=6,
                   log_every=100)
    # run 2: restores from step 6 and finishes
    params, hist = train_loop(cfg, steps=10, global_batch=4, seq_len=16,
                              ckpt_dir=tmp_path, ckpt_every=3,
                              log_every=100)
    assert len(hist) == 4            # steps 6..9 only (resumed, not replayed)
    losses = [h["loss"] for h in hist]
    assert all(np.isfinite(l) for l in losses)


def test_train_loss_decreases():
    from repro.configs import get_config
    from repro.launch.train import train_loop
    cfg = get_config("minitron_4b", smoke=True)
    _, hist = train_loop(cfg, steps=30, global_batch=8, seq_len=32,
                         log_every=100)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.1, (first, last)
