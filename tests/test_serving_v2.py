"""Sharded serving v2: LUT cache inside the sharded path, heat-aware
admission vs LRU, online heat + re-layout, per-bucket tasks_per_shard."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cluster_locate
from repro.core.sharded_search import DistributedEngine, EngineConfig
from repro.runtime import (HeatAwareAdmission, HotClusterLUTCache,
                           OnlineHeatEstimator, ServingConfig,
                           ServingRuntime, ShardedEngine,
                           TasksPerShardController)

NPROBE = 8


@pytest.fixture(scope="module")
def sample_probes(small_index, small_corpus):
    probes, _ = cluster_locate(small_corpus.queries.astype(jnp.float32),
                               small_index.centroids, NPROBE)
    return np.asarray(probes)


def _engine(small_index, sample_probes, **kw):
    cfg = EngineConfig(n_shards=4, nprobe=NPROBE, k=10, tasks_per_shard=512,
                       strategy="gather", dup_budget_bytes=1 << 17,
                       **{k: v for k, v in kw.items()
                          if k in EngineConfig.__dataclass_fields__})
    extra = {k: v for k, v in kw.items()
             if k not in EngineConfig.__dataclass_fields__}
    return DistributedEngine(small_index, cfg, sample_probes, **extra)


# ---------------------------------------------------------------------------
# Online heat estimation
# ---------------------------------------------------------------------------

def test_heat_estimator_units_match_offline():
    """heat() is expected accesses/query — same unit as estimate_heat."""
    from repro.core.layout import estimate_heat
    probes = np.array([[0, 1], [0, 2], [0, 1]])
    est = OnlineHeatEstimator(nlist=4, halflife_batches=1e9)  # ~no decay
    est.observe(probes)
    np.testing.assert_allclose(est.heat(), estimate_heat(probes, 4),
                               rtol=1e-9)
    assert est.heat_of(0) == pytest.approx(1.0)


def test_heat_estimator_decay_tracks_shift():
    """After the stream shifts, decayed heat must re-rank clusters."""
    est = OnlineHeatEstimator(nlist=8, halflife_batches=2.0)
    for _ in range(16):
        est.observe(np.full((4, 2), 0))            # cluster 0 hot
    assert est.heat_of(0) > est.heat_of(7)
    for _ in range(16):
        est.observe(np.full((4, 2), 7))            # traffic shifts to 7
    assert est.heat_of(7) > est.heat_of(0)
    assert est.batches_observed == 32


def test_heat_estimator_seeded_cold_start():
    seed = np.zeros(8)
    seed[3] = 2.0
    est = OnlineHeatEstimator(nlist=8, seed=seed)
    assert est.heat_of(3) == pytest.approx(2.0)    # offline heat preserved
    assert est.heat_of(0) == 0.0


# ---------------------------------------------------------------------------
# Heat-aware admission vs plain LRU
# ---------------------------------------------------------------------------

def _replay(cache, accesses):
    hits = 0
    for cluster, bucket in accesses:
        if cache.get_by_bucket(cluster, bucket) is not None:
            hits += 1
        else:
            cache.put_by_bucket(cluster, bucket, np.zeros(1, np.float32))
    return hits


def _skewed_accesses(rounds=20):
    """8 recurring hot keys (clusters 0–3) interleaved with a one-off cold
    scan (clusters 4+, fresh bucket each time) — classic LRU poison."""
    acc, cold = [], 0
    for _ in range(rounds):
        for h in range(8):
            acc.append((h % 4, h // 4))
        for _ in range(4):
            acc.append((4 + cold % 28, 10_000 + cold))
            cold += 1
    return acc


def test_heat_admission_beats_lru_on_skewed_stream():
    heat = np.full(32, 0.01)
    heat[:4] = 4.0
    est = OnlineHeatEstimator(nlist=32, seed=heat)
    acc = _skewed_accesses()
    lru = HotClusterLUTCache(capacity=8)
    aware = HotClusterLUTCache(capacity=8,
                               admission=HeatAwareAdmission(est))
    hits_lru = _replay(lru, acc)
    hits_aware = _replay(aware, acc)
    # cold scan inserts are rejected, hot entries survive every round
    assert hits_aware > hits_lru
    assert aware.stats.rejects > 0
    assert aware.stats.hit_rate > 0.5
    assert len(aware) <= 8 and len(lru) <= 8


def test_heat_admission_degrades_to_lru_on_flat_heat():
    """All-equal heat: ties admit, evict the oldest — plain LRU behaviour."""
    est = OnlineHeatEstimator(nlist=8)                  # all-zero heat
    aware = HotClusterLUTCache(capacity=2,
                               admission=HeatAwareAdmission(est))
    aware.put_by_bucket(0, 0, np.zeros(1))
    aware.put_by_bucket(1, 0, np.zeros(1))
    aware.put_by_bucket(2, 0, np.zeros(1))              # evicts (0, 0)
    assert aware.stats.rejects == 0
    assert aware.get_by_bucket(0, 0) is None
    assert aware.get_by_bucket(2, 0) is not None


# ---------------------------------------------------------------------------
# LUT cache inside the sharded path
# ---------------------------------------------------------------------------

def test_sharded_cache_matches_uncached(small_index, small_corpus,
                                        sample_probes):
    """Cache on vs off: same neighbors, distances to float round-off; a
    repeated batch is served fully from cache and is bit-identical."""
    queries = jnp.asarray(small_corpus.queries[:8], jnp.float32)
    plain = _engine(small_index, sample_probes)
    cache = HotClusterLUTCache(capacity=2048)
    cached = _engine(small_index, sample_probes, lut_cache=cache)
    d0, i0, _ = plain.search(queries)
    d1, i1, _ = cached.search(queries)
    np.testing.assert_array_equal(i1, i0)
    np.testing.assert_allclose(d1, d0, rtol=1e-5, atol=1e-5)
    assert cache.stats.misses == 8 * NPROBE and cache.stats.hits == 0
    d2, i2, _ = cached.search(queries)          # every (q, cluster) pair hits
    assert cache.stats.hits == 8 * NPROBE
    np.testing.assert_array_equal(i2, i1)
    np.testing.assert_array_equal(d2, d1)


def test_sharded_served_with_cache_matches_direct(small_index, small_corpus,
                                                  sample_probes):
    """Skewed stream through the runtime over the sharded engine with the
    cache on: served results == direct batched search, and repeats hit."""
    queries = np.asarray(small_corpus.queries[:6])
    cache = HotClusterLUTCache(capacity=2048)
    adapter = ShardedEngine(_engine(small_index, sample_probes,
                                    lut_cache=cache))
    direct_d, direct_i = adapter.search_batch(queries)
    rt = ServingRuntime(adapter, ServingConfig(buckets=(1, 2, 4),
                                               max_wait_s=1e-4))
    rt.warmup(queries.shape[1])
    stream = [(i * 1e-3, queries[i % len(queries)]) for i in range(12)]
    reqs = rt.run_stream(stream)
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(r.ids, direct_i[i % len(queries)])
    m = rt.metrics()
    assert m["lut_cache"]["hits"] >= 6 * NPROBE
    # +1: the direct search_batch() reference call above is also a batch
    assert m["engine"]["batches"] == len(rt.stats.batches) + 1


def test_pad_rows_bypass_sharded_cache_and_heat(small_index, small_corpus,
                                                sample_probes):
    """Serving padding must not reach the cache or the heat estimator."""
    queries = np.asarray(small_corpus.queries[:6])
    est = OnlineHeatEstimator(small_index.nlist)
    cache = HotClusterLUTCache(capacity=2048,
                               admission=HeatAwareAdmission(est))
    adapter = ShardedEngine(_engine(small_index, sample_probes,
                                    lut_cache=cache, heat_estimator=est))
    rt = ServingRuntime(adapter, ServingConfig(buckets=(4,), max_wait_s=1e-4))
    rt.warmup(queries.shape[1])
    assert est.batches_observed == 0                 # warmup is all padding
    assert cache.stats.lookups == 0 and len(cache) == 0
    # one valid request per deadline-flushed batch of 4 -> 3 pad rows each
    reqs = rt.run_stream([(i * 1e-3, queries[i]) for i in range(6)])
    assert cache.stats.lookups == 6 * NPROBE         # pads never looked up
    assert est.batches_observed == 6
    direct_d, direct_i = adapter.search_batch(queries)
    np.testing.assert_array_equal(np.stack([r.ids for r in reqs]), direct_i)


def test_lut_step_masks_bankless_tasks(small_index, sample_probes):
    """A task with lidx == -1 (a flush=False carry-over whose cluster this
    batch didn't probe) must be invalidated — never scored against bank
    row 0."""
    import jax.numpy as jnp2
    from repro.core.sharded_search import run_shards_vmap_lut
    eng = _engine(small_index, sample_probes)
    s = eng.sindex.n_shards
    qidx = jnp2.zeros((s, 4), jnp2.int32)              # "valid" query 0
    sidx = jnp2.zeros((s, 4), jnp2.int32)              # real slot
    lidx = jnp2.full((s, 4), -1, jnp2.int32)           # ...but no bank row
    bank = jnp2.zeros((1, small_index.codebook.m, small_index.codebook.cb),
                      jnp2.float32)
    bd, bi = run_shards_vmap_lut(eng.sindex, qidx, sidx, lidx, bank,
                                 k=eng.cfg.k, strategy="gather")
    assert bool(jnp2.all(jnp2.isinf(bd))) and bool(jnp2.all(bi == -1))


# ---------------------------------------------------------------------------
# Per-bucket tasks_per_shard tuning
# ---------------------------------------------------------------------------

def test_tasks_controller_widths_and_adaptation():
    ctrl = TasksPerShardController(n_shards=4, tasks_per_query=8.0,
                                   headroom=1.5, floor=4, cap=256)
    assert ctrl.tasks_for(1) == 4                    # floor
    assert ctrl.tasks_for(32) == 128                 # pow2(ceil(96))
    assert ctrl.tasks_for(10_000) == 256             # capped at static width
    ctrl.observe(32, n_deferred=5)                   # hard-cap overflow
    assert ctrl.tasks_for(32) == 256
    ctrl.observe(10_000, n_deferred=5)               # already at cap: no-op
    assert ctrl.overflows == 1
    assert ctrl.summary()["boosted"] == {32: 256}
    # perf-model latency budget caps the width independently
    timed = TasksPerShardController(n_shards=4, tasks_per_query=8.0,
                                    floor=4, cap=256, mean_task_s=1e-3,
                                    max_shard_time_s=8e-3)
    assert timed.tasks_for(1024) == 8
    # overflow boosts are inert (and bounded) while the budget cap binds
    for _ in range(100):
        timed.observe(1024, n_deferred=3)
    assert timed.tasks_for(1024) == 8 and timed.overflows == 0
    # retune re-prices the prediction after a re-layout
    ctrl.retune(tasks_per_query=16.0)
    assert ctrl.tasks_for(1) == 8                    # was 4 at tpq=8


def test_tasks_controller_never_degrades(small_index, small_corpus,
                                         sample_probes):
    """Tuned widths must shrink the static table without changing results
    or adding drain rounds."""
    queries = jnp.asarray(small_corpus.queries[:16], jnp.float32)
    static = _engine(small_index, sample_probes)
    tuned = _engine(small_index, sample_probes)
    tuned.tasks_controller = tuned.make_tasks_controller()
    width = tuned.tasks_controller.tasks_for(16)
    assert width <= static.cfg.tasks_per_shard
    d0, i0, info0 = static.search(queries)
    d1, i1, info1 = tuned.search(queries)
    np.testing.assert_allclose(np.sort(d1, axis=1), np.sort(d0, axis=1),
                               rtol=1e-5, atol=1e-5)
    for q in range(i0.shape[0]):                     # same neighbor sets
        assert set(i1[q].tolist()) == set(i0[q].tolist())
    assert info1["rounds"] <= info0["rounds"] + 1
    assert tuned.tasks_controller.overflows == 0


# ---------------------------------------------------------------------------
# Heat-driven re-layout
# ---------------------------------------------------------------------------

def test_refresh_layout_preserves_results(small_index, small_corpus,
                                          sample_probes):
    """Re-layout changes placement, never results; carry is reset and the
    relayout counter advances."""
    queries = jnp.asarray(small_corpus.queries[:8], jnp.float32)
    est = OnlineHeatEstimator(small_index.nlist)
    eng = _engine(small_index, sample_probes, heat_estimator=est)
    d0, i0, _ = eng.search(queries)
    # observe a strongly shifted stream, then re-layout from it
    hot = np.asarray(sample_probes[:8])
    for _ in range(8):
        est.observe(hot)
    stats = eng.refresh_layout()
    assert eng.relayouts == 1 and eng.carry == []
    assert np.isfinite(stats["imbalance_after"])
    d1, i1, _ = eng.search(queries)
    np.testing.assert_allclose(np.sort(d1, axis=1), np.sort(d0, axis=1),
                               rtol=1e-5, atol=1e-5)
    for q in range(i0.shape[0]):
        assert set(i1[q].tolist()) == set(i0[q].tolist())


def test_periodic_relayout_in_serving(small_index, small_corpus,
                                      sample_probes):
    """relayout_every triggers mid-stream and served results still match
    a direct search."""
    queries = np.asarray(small_corpus.queries[:4])
    est = OnlineHeatEstimator(small_index.nlist)
    adapter = ShardedEngine(_engine(small_index, sample_probes,
                                    relayout_every=3, heat_estimator=est))
    direct_d, direct_i = adapter.search_batch(queries)
    rt = ServingRuntime(adapter, ServingConfig(buckets=(1, 2),
                                               max_wait_s=1e-4))
    reqs = rt.run_stream([(i * 1e-3, queries[i % 4]) for i in range(8)])
    assert adapter.engine.relayouts >= 1
    for i, r in enumerate(reqs):
        assert set(r.ids.tolist()) == set(direct_i[i % 4].tolist())
