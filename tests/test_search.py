import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SearchParams, search_ivfpq, exact_search, recall_at_k,
                        cluster_locate, build_ivfpq, pad_clusters)


def test_exact_search_oracle(small_corpus):
    pts = small_corpus.points.astype(jnp.float32)
    qs = small_corpus.queries.astype(jnp.float32)
    d, i = exact_search(pts, qs, k=10)
    # distances ascending, ids valid
    dn = np.asarray(d)
    assert (np.diff(dn, axis=1) >= -1e-3).all()
    assert (np.asarray(i) >= 0).all() and (np.asarray(i) < pts.shape[0]).all()
    # first neighbor is genuinely the argmin for a spot-checked query
    full = np.sum((np.asarray(qs[0])[None] - np.asarray(pts)) ** 2, -1)
    assert int(i[0, 0]) == int(full.argmin())


def test_cluster_locate_shapes(small_index, small_corpus):
    q = small_corpus.queries.astype(jnp.float32)
    probes, dists = cluster_locate(q, small_index.centroids, nprobe=8)
    assert probes.shape == (q.shape[0], 8)
    assert (np.asarray(probes) < small_index.nlist).all()
    # probes sorted by distance ascending
    assert (np.diff(np.asarray(dists), axis=1) >= -1e-3).all()


def test_recall_constraint_paper(small_index, small_clusters, small_corpus):
    """Paper §V-A: all experiments under recall@10 >= 0.8 — reproduce it."""
    p = SearchParams(nprobe=16, k=10, query_chunk=64)
    _, ids = search_ivfpq(small_index, small_clusters, small_corpus.queries, p)
    r = float(recall_at_k(ids, small_corpus.groundtruth))
    assert r >= 0.8, f"recall@10 = {r}"


def test_recall_monotonic_in_nprobe(small_index, small_clusters, small_corpus):
    rs = []
    for nprobe in (1, 4, 16):
        p = SearchParams(nprobe=nprobe, k=10, query_chunk=64)
        _, ids = search_ivfpq(small_index, small_clusters,
                              small_corpus.queries, p)
        rs.append(float(recall_at_k(ids, small_corpus.groundtruth)))
    assert rs[0] <= rs[1] + 0.02 and rs[1] <= rs[2] + 0.02


def test_gather_and_onehot_agree(small_index, small_clusters, small_corpus):
    pg = SearchParams(nprobe=8, k=10, strategy="gather", query_chunk=64)
    po = SearchParams(nprobe=8, k=10, strategy="onehot", query_chunk=64)
    dg, ig = search_ivfpq(small_index, small_clusters, small_corpus.queries, pg)
    do, io = search_ivfpq(small_index, small_clusters, small_corpus.queries, po)
    np.testing.assert_allclose(np.asarray(dg), np.asarray(do), rtol=1e-4,
                               atol=1e-2)
    # id lists may differ only at distance ties
    same = (np.asarray(ig) == np.asarray(io)).mean()
    assert same > 0.97


def test_search_handles_nonmultiple_query_count(small_index, small_clusters,
                                                small_corpus):
    p = SearchParams(nprobe=4, k=5, query_chunk=30)  # 64 % 30 != 0
    d, i = search_ivfpq(small_index, small_clusters,
                        small_corpus.queries, p)
    assert d.shape == (64, 5) and i.shape == (64, 5)
    assert np.isfinite(np.asarray(d)).all()


def test_opq_pipeline_end_to_end(small_corpus):
    idx = build_ivfpq(jax.random.PRNGKey(1), small_corpus.points, nlist=32,
                      m=16, cb=128, kmeans_iters=4, pq_iters=4, opq=True)
    clusters = pad_clusters(idx)
    p = SearchParams(nprobe=8, k=10, query_chunk=64)
    _, ids = search_ivfpq(idx, clusters, small_corpus.queries, p)
    r = float(recall_at_k(ids, small_corpus.groundtruth))
    assert r >= 0.6  # OPQ path functional and reasonably accurate
