"""End-to-end distributed DRIM-ANN through the service layer: one
ServiceSpec per configuration stands up the sharded engine (layout
optimization — split/duplicate/heat-allocate — plus runtime scheduling
with the batch filter) over 8 simulated 'DPU' shards; the ablation
toggles the naive layout/schedule via ``engine_overrides``.

    PYTHONPATH=src python examples/distributed_anns.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import cluster_locate, recall_at_k
from repro.data import make_clustered_corpus
from repro.service import AnnService, IndexSpec, ServiceSpec


def main():
    ds = make_clustered_corpus(seed=0, n=20_000, d=32, n_queries=128,
                               n_components=32, k_gt=10, zipf_a=1.3)

    index = None      # built by the first spec, shared by the second
    for name, split_max, dup_bytes, overrides in (
            ("naive (ID-order, no balance)", 10 ** 9, 0,
             dict(naive_layout=True, naive_schedule=True)),
            ("DRIM-ANN (split+dup+alloc+sched)", 256, 1 << 20, None)):
        spec = ServiceSpec(
            engine="sharded", nprobe=16, k=10, strategy="gather",
            index=IndexSpec(nlist=64, m=16, cb=256),
            n_shards=8, tasks_per_shard=512,
            split_max=split_max, dup_budget_bytes=dup_bytes,
            engine_overrides=overrides)
        svc = AnnService.build(spec, points=ds.points, index=index,
                               sample_queries=ds.queries)
        index = svc.index
        d, ids = svc.search(ds.queries)
        r = float(recall_at_k(jnp.asarray(ids), ds.groundtruth))

        # layout/scheduler internals for the ablation readout (probe lists
        # at the paper's heat-sample width, as in the original ablation)
        eng = svc.core_engine()                       # DistributedEngine
        stats = eng.layout.stats(eng.latency)
        probes, _ = cluster_locate(ds.queries.astype(jnp.float32),
                                   eng.index.centroids, 8)
        sched = eng.schedule(probes=np.asarray(probes))
        eng.carry = []
        print(f"{name}:")
        print(f"  recall@10={r:.3f}  layout imbalance="
              f"{stats['imbalance']:.2f}  predicted makespan="
              f"{sched.predicted_load.max() * 1e3:.2f}ms")
        svc.shutdown()


if __name__ == "__main__":
    main()
