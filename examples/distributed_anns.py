"""End-to-end distributed DRIM-ANN: layout optimization (split/duplicate/
heat-allocate), runtime scheduling with the batch filter, and the sharded
search engine over 8 simulated 'DPU' shards.

    PYTHONPATH=src python examples/distributed_anns.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_ivfpq, cluster_locate, recall_at_k
from repro.core.sharded_search import DistributedEngine, EngineConfig
from repro.data import make_clustered_corpus


def main():
    ds = make_clustered_corpus(seed=0, n=20_000, d=32, n_queries=128,
                               n_components=32, k_gt=10, zipf_a=1.3)
    index = build_ivfpq(jax.random.PRNGKey(0), ds.points, nlist=64, m=16,
                        cb=256)
    # heat estimated from a sample query set (paper §IV-C)
    probes, _ = cluster_locate(ds.queries.astype(jnp.float32),
                               index.centroids, 8)

    for name, kw in (
            ("naive (ID-order, no balance)",
             dict(naive_layout=True, naive_schedule=True,
                  split_max=10 ** 9)),
            ("DRIM-ANN (split+dup+alloc+sched)",
             dict(split_max=256, dup_budget_bytes=1 << 20))):
        cfg = EngineConfig(n_shards=8, nprobe=16, k=10, tasks_per_shard=512,
                           strategy="gather", **kw)
        eng = DistributedEngine(index, cfg, np.asarray(probes))
        d, ids, info = eng.search(ds.queries)
        r = float(recall_at_k(jnp.asarray(ids), ds.groundtruth))
        stats = eng.layout.stats(eng.latency)
        sched = eng._schedule(np.asarray(probes))
        eng.carry = []
        print(f"{name}:")
        print(f"  recall@10={r:.3f}  layout imbalance="
              f"{stats['imbalance']:.2f}  predicted makespan="
              f"{sched.predicted_load.max() * 1e3:.2f}ms  rounds="
              f"{info['rounds']}")


if __name__ == "__main__":
    main()
