"""RAG serving end to end through the service layer: one ServiceSpec
stands up the whole retrieval tier — sharded DRIM-ANN engines, LUT
caches, micro-batching runtimes, a cache-aware multi-replica router —
and a *stream* of single-query requests flows through it into an LM's
decode loop, the paper's motivating application (§I).

Pipeline: ServiceSpec -> AnnService.build -> routed query stream ->
per-replica micro-batches -> sharded ANNS top-k -> de-padded per-request
results (verified against a direct batched search) -> retrieved vectors
become prefix context embeddings -> batched LM decode.

    PYTHONPATH=src python examples/rag_serving.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data import make_clustered_corpus
from repro.launch.serve import generate
from repro.models import init_params
from repro.service import AnnService, IndexSpec, ServiceSpec


def main():
    # --- one spec for the whole retrieval tier ---------------------------
    d_embed = 32
    n_queries = 8
    ds = make_clustered_corpus(seed=0, n=10_000, d=d_embed,
                               n_queries=n_queries, n_components=16)
    spec = ServiceSpec(
        engine="sharded", replicas=2, router="cache_aware",
        nprobe=8, k=4, strategy="gather",
        index=IndexSpec(nlist=32, m=8, cb=64),
        n_shards=4, tasks_per_shard=256,
        buckets=(1, 2, 4), max_wait_s=1e-3,
        cache_capacity=1024)
    svc = AnnService.build(spec, points=ds.points, sample_queries=ds.queries)
    svc.warmup()                          # compile each bucket shape once

    # --- stream single-query requests through the router -----------------
    queries = np.asarray(ds.queries, np.float32)
    stream = [(i * 4e-4, queries[i % n_queries])
              for i in range(2 * n_queries)]            # each query repeats
    requests = svc.stream(stream)
    doc_ids = np.stack([r.ids for r in requests[:n_queries]])

    # served results must match a direct batched search per query
    # (neighbor sets: the sharded merge may permute equal-distance ties)
    direct_d, direct_i = svc.search(queries)
    for i, r in enumerate(requests):
        assert set(r.ids.tolist()) == set(direct_i[i % n_queries].tolist()), \
            "serving != direct search"
    st = svc.stats()
    agg, rt = st["aggregate"], st["router"]
    print(f"served {agg['requests']} requests over {svc.n_replicas} "
          f"replicas in {agg['batches']} micro-batches "
          f"(router={rt['policy']} picks={rt['picks']})")
    print(f"latency p50={agg['p50_ms']:.2f}ms p99={agg['p99_ms']:.2f}ms"
          f" qps={agg['qps']:.0f}"
          f" lut_hit_rate={agg.get('lut_hit_rate', 0.0):.2f}")
    print("retrieved doc ids per query:", doc_ids.tolist())

    # --- generation tier: vision-style cross-attn LM over retrieved ctx --
    cfg = registry.get_config("llama32_vision_11b", smoke=True)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    batch = queries.shape[0]
    # retrieved document vectors -> context embeddings (stub projection)
    retrieved = np.asarray(ds.points)[np.maximum(doc_ids, 0)]   # (B, k, d)
    proj = np.random.default_rng(0).normal(
        0, 0.02, size=(d_embed, cfg.d_model))
    ctx = jnp.asarray(retrieved.astype(np.float32) @ proj)      # (B, k, dm)
    ctx = jnp.pad(ctx, ((0, 0), (0, cfg.vision_ctx - ctx.shape[1]), (0, 0)))

    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, 8), 0,
                                 cfg.vocab_size)
    toks = generate(cfg, params, prompts, gen_len=12, ctx=ctx)
    print("generated token ids (first query):", toks[0].tolist())
    print("RAG pipeline OK: routed streaming retrieval -> generation")
    svc.shutdown()


if __name__ == "__main__":
    main()
