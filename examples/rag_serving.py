"""RAG serving end to end: a *stream* of single-query requests flows
through the online serving runtime (micro-batching + hot-cluster LUT
cache) into the distributed DRIM-ANN engine, and the retrieved documents
feed an LM's decode loop — the paper's motivating application (§I).

Pipeline: query stream -> micro-batcher (bucketed, deadline-flushed)
-> sharded ANNS top-k -> de-padded per-request results (verified
identical to a direct batched search) -> retrieved vectors become
prefix context embeddings -> batched LM decode continues the prompt.

    PYTHONPATH=src python examples/rag_serving.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import (SearchParams, build_ivfpq, cluster_locate,
                        pad_clusters)
from repro.core.sharded_search import DistributedEngine, EngineConfig
from repro.data import make_clustered_corpus
from repro.launch.serve import generate
from repro.models import init_params
from repro.runtime import (HotClusterLUTCache, LocalEngine, ServingConfig,
                           ServingRuntime, ShardedEngine)


def main():
    # --- retrieval tier: DRIM-ANN over a document-embedding corpus -------
    d_embed = 32
    n_queries = 8
    ds = make_clustered_corpus(seed=0, n=10_000, d=d_embed,
                               n_queries=n_queries, n_components=16)
    index = build_ivfpq(jax.random.PRNGKey(0), ds.points, nlist=32, m=8,
                        cb=64)
    probes, _ = cluster_locate(ds.queries.astype(jnp.float32),
                               index.centroids, 8)
    eng = DistributedEngine(
        index, EngineConfig(n_shards=4, nprobe=8, k=4, tasks_per_shard=256,
                            strategy="gather"), np.asarray(probes))

    # --- online serving: stream single-query requests through the -------
    # micro-batcher into the sharded engine (one jit shape per bucket)
    runtime = ServingRuntime(
        ShardedEngine(eng),
        ServingConfig(buckets=(1, 2, 4), max_wait_s=1e-3))
    queries = np.asarray(ds.queries, np.float32)
    runtime.warmup(d_embed)               # compile each bucket shape once
    stream = [(i * 4e-4, queries[i]) for i in range(n_queries)]  # 2.5k QPS
    requests = runtime.run_stream(stream)
    doc_ids = np.stack([r.ids for r in requests])

    # served results must match one direct batched engine call exactly
    direct_d, direct_i, _ = eng.search(ds.queries)
    assert np.array_equal(doc_ids, direct_i), "serving != direct search"
    m = runtime.metrics()
    print(f"served {m['requests']} requests in {m['batches']} micro-batches"
          f" (flushes: {m['flushes']})")
    print(f"latency p50={m['p50_ms']:.2f}ms p99={m['p99_ms']:.2f}ms"
          f" qps={m['qps']:.0f} occupancy={m['avg_batch_occupancy']:.2f}")
    print("retrieved doc ids per query:", doc_ids.tolist())

    # --- hot-cluster cache: repeat traffic skips LC work -----------------
    cached = LocalEngine(index, pad_clusters(index),
                         SearchParams(nprobe=8, k=4, strategy="gather"),
                         lut_cache=HotClusterLUTCache(capacity=1024))
    cached.search_batch(queries)          # cold pass fills the cache
    cached.search_batch(queries)          # repeat traffic hits
    print("LUT cache after repeat pass:", cached.lut_cache.stats.as_dict())

    # --- generation tier: vision-style cross-attn LM over retrieved ctx --
    cfg = registry.get_config("llama32_vision_11b", smoke=True)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    batch = queries.shape[0]
    # retrieved document vectors -> context embeddings (stub projection)
    retrieved = np.asarray(ds.points)[np.maximum(doc_ids, 0)]   # (B, k, d)
    proj = np.random.default_rng(0).normal(
        0, 0.02, size=(d_embed, cfg.d_model))
    ctx = jnp.asarray(retrieved.astype(np.float32) @ proj)      # (B, k, dm)
    ctx = jnp.pad(ctx, ((0, 0), (0, cfg.vision_ctx - ctx.shape[1]), (0, 0)))

    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, 8), 0,
                                 cfg.vocab_size)
    toks = generate(cfg, params, prompts, gen_len=12, ctx=ctx)
    print("generated token ids (first query):", toks[0].tolist())
    print("RAG pipeline OK: streamed retrieval -> cross-attended generation")


if __name__ == "__main__":
    main()
