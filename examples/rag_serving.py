"""RAG serving: the DRIM-ANN engine as the retrieval tier feeding an LM's
decode loop — retrieval-augmented generation end to end (the paper's
motivating application, §I).

Pipeline: query embedding -> distributed ANNS top-k -> retrieved vectors
become prefix context embeddings -> batched LM decode continues the prompt.

    PYTHONPATH=src python examples/rag_serving.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.core import build_ivfpq, cluster_locate
from repro.core.sharded_search import DistributedEngine, EngineConfig
from repro.data import make_clustered_corpus
from repro.launch.serve import generate
from repro.models import init_params


def main():
    # --- retrieval tier: DRIM-ANN over a document-embedding corpus -------
    d_embed = 32
    ds = make_clustered_corpus(seed=0, n=10_000, d=d_embed, n_queries=4,
                               n_components=16)
    index = build_ivfpq(jax.random.PRNGKey(0), ds.points, nlist=32, m=8,
                        cb=64)
    probes, _ = cluster_locate(ds.queries.astype(jnp.float32),
                               index.centroids, 8)
    eng = DistributedEngine(
        index, EngineConfig(n_shards=4, nprobe=8, k=4, tasks_per_shard=256,
                            strategy="gather"), np.asarray(probes))
    _, doc_ids, _ = eng.search(ds.queries)
    print("retrieved doc ids per query:", doc_ids.tolist())

    # --- generation tier: vision-style cross-attn LM over retrieved ctx --
    cfg = registry.get_config("llama32_vision_11b", smoke=True)
    params, _ = init_params(jax.random.PRNGKey(0), cfg)
    batch = ds.queries.shape[0]
    # retrieved document vectors -> context embeddings (stub projection)
    retrieved = np.asarray(ds.points)[np.maximum(doc_ids, 0)]   # (B, k, d)
    proj = np.random.default_rng(0).normal(
        0, 0.02, size=(d_embed, cfg.d_model))
    ctx = jnp.asarray(retrieved.astype(np.float32) @ proj)      # (B, k, dm)
    ctx = jnp.pad(ctx, ((0, 0), (0, cfg.vision_ctx - ctx.shape[1]), (0, 0)))

    prompts = jax.random.randint(jax.random.PRNGKey(1), (batch, 8), 0,
                                 cfg.vocab_size)
    toks = generate(cfg, params, prompts, gen_len=12, ctx=ctx)
    print("generated token ids (first query):", toks[0].tolist())
    print("RAG pipeline OK: retrieval -> cross-attended generation")


if __name__ == "__main__":
    main()
