"""Train a reduced-config LM (any of the 10 assigned architectures) for a
few hundred steps with checkpointing — the end-to-end training driver.

    PYTHONPATH=src python examples/train_lm.py [--arch qwen3_14b]
                                               [--steps 200]
"""

import argparse

from repro.configs import registry
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron_4b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, smoke=True)
    print(f"training {cfg.name} for {args.steps} steps "
          f"(ckpt -> {args.ckpt_dir})")
    _, hist = train_loop(cfg, steps=args.steps, global_batch=8, seq_len=64,
                         ckpt_dir=args.ckpt_dir, ckpt_every=50,
                         log_every=20)
    losses = [h["loss"] for h in hist]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'improved' if losses[-1] < losses[0] else 'check config'})")


if __name__ == "__main__":
    main()
