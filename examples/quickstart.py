"""Quickstart: build an IVF-PQ index and search it with the five-phase
DRIM-ANN pipeline, validating the paper's recall@10 >= 0.8 regime.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (build_ivfpq, pad_clusters, SearchParams,
                        search_ivfpq, exact_search, recall_at_k)
from repro.data import make_clustered_corpus


def main():
    print("generating a SIFT-like clustered uint8 corpus ...")
    ds = make_clustered_corpus(seed=0, n=20_000, d=32, n_queries=128,
                               n_components=32, k_gt=10)

    print("building IVF-PQ (nlist=64, M=16, CB=256) ...")
    index = build_ivfpq(jax.random.PRNGKey(0), ds.points, nlist=64, m=16,
                        cb=256)
    clusters = pad_clusters(index)

    params = SearchParams(nprobe=16, k=10)
    dists, ids = search_ivfpq(index, clusters, ds.queries, params)
    r = float(recall_at_k(ids, ds.groundtruth))
    print(f"recall@10 = {r:.3f}  (paper constraint: >= 0.8)")
    assert r >= 0.8

    # the same search through the Pallas kernel path (interpret on CPU)
    params_k = SearchParams(nprobe=16, k=10, use_kernels=True,
                            query_chunk=32)
    _, ids_k = search_ivfpq(index, clusters, ds.queries, params_k)
    rk = float(recall_at_k(ids_k, ds.groundtruth))
    print(f"recall@10 via Pallas kernels = {rk:.3f}")

    q = ds.queries[0]
    print(f"query 0 neighbors: {ids[0].tolist()}")
    print(f"          dists^2: {[round(float(d), 1) for d in dists[0]]}")


if __name__ == "__main__":
    main()
