"""Diff two benchmark JSON snapshots (``benchmarks.run --json`` output).

    python tools/bench_compare.py BENCH_quick.json BENCH_fresh.json \
        [--threshold 1.5] [--fail-on-regress]

Per row shared by both files, prints old/new ms and the ratio; rows
slower than ``threshold`` x old are flagged ``REGRESS`` (and rows
``1/threshold`` x faster flagged ``IMPROVE``) — the start of the
regression-gate trajectory the ROADMAP asks for.  Rows present in only
one file are listed as added/removed, never flagged: a new benchmark is
not a regression.

Exit code is 0 unless ``--fail-on-regress`` is given and at least one
row regressed.  CI runs this as a *non-blocking* step against the
committed ``BENCH_quick.json`` (CPU timing variance across runners is
not yet understood well enough to gate merges — the ROADMAP tracks
flipping ``--fail-on-regress`` on once it is).

Schema per file: ``[{"suite": str, "rows": [{"name", "ms", "note"}],
"meta": {...}}, ...]`` — suites that errored (``meta.error``) contribute
no rows and are reported.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Tuple


def load_rows(path: str) -> Tuple[Dict[str, float], list]:
    """{row name -> ms} plus the names of suites that errored."""
    with open(path) as f:
        suites = json.load(f)
    rows: Dict[str, float] = {}
    errored = []
    for suite in suites:
        if suite.get("meta", {}).get("error"):
            errored.append(suite.get("suite", "?"))
        for row in suite.get("rows", []):
            rows[row["name"]] = float(row["ms"])
    return rows, errored


def compare(old: Dict[str, float], new: Dict[str, float],
            threshold: float) -> dict:
    """Row-by-row delta report: {common, regressed, improved, added,
    removed}; ``common`` maps name -> (old_ms, new_ms, ratio)."""
    common = {}
    regressed, improved = [], []
    for name in sorted(set(old) & set(new)):
        o, n = old[name], new[name]
        ratio = n / o if o > 0 else float("inf")
        common[name] = (o, n, ratio)
        if ratio > threshold:
            regressed.append(name)
        elif ratio < 1.0 / threshold:
            improved.append(name)
    return {
        "common": common,
        "regressed": regressed,
        "improved": improved,
        "added": sorted(set(new) - set(old)),
        "removed": sorted(set(old) - set(new)),
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", help="baseline BENCH_*.json (e.g. committed)")
    ap.add_argument("new", help="fresh BENCH_*.json to compare")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="flag rows slower than this ratio (default 1.5)")
    ap.add_argument("--fail-on-regress", action="store_true",
                    help="exit 1 when any row regressed (CI gate; off "
                         "while run-to-run variance is being charted)")
    args = ap.parse_args()
    if args.threshold <= 1.0:
        ap.error(f"--threshold must be > 1.0, got {args.threshold}")

    old, old_err = load_rows(args.old)
    new, new_err = load_rows(args.new)
    rep = compare(old, new, args.threshold)

    print(f"{'row':40s} {'old_ms':>10s} {'new_ms':>10s} {'ratio':>7s}")
    for name, (o, n, ratio) in rep["common"].items():
        flag = ("  REGRESS" if name in rep["regressed"]
                else "  IMPROVE" if name in rep["improved"] else "")
        print(f"{name:40s} {o:10.3f} {n:10.3f} {ratio:6.2f}x{flag}")
    for name in rep["added"]:
        print(f"{name:40s} {'-':>10s} {new[name]:10.3f}   added")
    for name in rep["removed"]:
        print(f"{name:40s} {old[name]:10.3f} {'-':>10s}   removed")
    for label, errs in (("old", old_err), ("new", new_err)):
        if errs:
            print(f"# {label}: errored suites (no rows): {errs}")
    print(f"# {len(rep['common'])} compared, {len(rep['regressed'])} "
          f"regressed (> {args.threshold:.2f}x), {len(rep['improved'])} "
          f"improved, {len(rep['added'])} added, {len(rep['removed'])} "
          f"removed")
    if args.fail_on_regress and rep["regressed"]:
        return 1
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:      # output piped into head/less and closed
        sys.exit(0)
