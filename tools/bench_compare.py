"""Diff two benchmark JSON snapshots (``benchmarks.run --json`` output).

    python tools/bench_compare.py BENCH_quick.json BENCH_fresh.json \
        [--threshold 1.5] [--fail-on-regress] [--gate-all]

Per row shared by both files, prints old/new ms and the ratio; rows
slower than ``threshold`` x old are flagged ``REGRESS`` (and rows
``1/threshold`` x faster flagged ``IMPROVE``).  Rows present in only
one file are listed as added/removed, never flagged: a new benchmark is
not a regression.

Gating: with ``--fail-on-regress`` the exit code is 1 when any *gated*
row regressed.  A row is gated when it is tagged ``stable: true`` in
BOTH snapshots — the PIM-paced rows, whose service time is the Eq. 15
model rather than host scheduling (the unpaced virtual-clock rows swing
0.1-5x run-to-run on this container and are reported, never gated).
``--gate-all`` widens the gate to every common row (local debugging of
a perf change; too noisy for CI).  CI runs ``--fail-on-regress``
against the committed ``BENCH_quick.json``.

Schema per file: ``[{"suite": str, "rows": [{"name", "ms", "stable",
"note"}], "meta": {...}}, ...]`` — ``stable`` is optional (older
snapshots predate it; their rows are never gated) and suites that
errored (``meta.error``) contribute no rows and are reported.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, Set, Tuple


def load_rows(path: str) -> Tuple[Dict[str, float], Set[str], list]:
    """({row name -> ms}, {stable-tagged row names}, errored suites)."""
    with open(path) as f:
        suites = json.load(f)
    rows: Dict[str, float] = {}
    stable: Set[str] = set()
    errored = []
    for suite in suites:
        if suite.get("meta", {}).get("error"):
            errored.append(suite.get("suite", "?"))
        for row in suite.get("rows", []):
            rows[row["name"]] = float(row["ms"])
            if row.get("stable"):
                stable.add(row["name"])
    return rows, stable, errored


def compare(old: Dict[str, float], new: Dict[str, float],
            threshold: float, gated: Set[str] = frozenset()) -> dict:
    """Row-by-row delta report: {common, regressed, improved, added,
    removed, gated_regressed}; ``common`` maps name ->
    (old_ms, new_ms, ratio).  ``gated_regressed`` is the subset of
    ``regressed`` inside ``gated`` — what --fail-on-regress acts on."""
    common = {}
    regressed, improved = [], []
    for name in sorted(set(old) & set(new)):
        o, n = old[name], new[name]
        if o > 0:
            ratio = n / o
        else:
            # a 0ms baseline is a value-encoding row (e.g. a boolean
            # parity encoded as 0/epsilon) — equal-zero is parity, not
            # an infinite regression
            ratio = 1.0 if n <= 0 else float("inf")
        common[name] = (o, n, ratio)
        if ratio > threshold:
            regressed.append(name)
        elif ratio < 1.0 / threshold:
            improved.append(name)
    return {
        "common": common,
        "regressed": regressed,
        "improved": improved,
        "gated_regressed": [n for n in regressed if n in gated],
        "added": sorted(set(new) - set(old)),
        "removed": sorted(set(old) - set(new)),
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("old", help="baseline BENCH_*.json (e.g. committed)")
    ap.add_argument("new", help="fresh BENCH_*.json to compare")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="flag rows slower than this ratio (default 1.5)")
    ap.add_argument("--fail-on-regress", action="store_true",
                    help="exit 1 when any gated row regressed (gated = "
                         "stable-tagged in both snapshots; the CI gate)")
    ap.add_argument("--gate-all", action="store_true",
                    help="with --fail-on-regress: gate every common row, "
                         "not just the stable-tagged ones")
    args = ap.parse_args()
    if args.threshold <= 1.0:
        ap.error(f"--threshold must be > 1.0, got {args.threshold}")

    old, old_stable, old_err = load_rows(args.old)
    new, new_stable, new_err = load_rows(args.new)
    gated = (set(old) & set(new)) if args.gate_all \
        else (old_stable & new_stable)
    rep = compare(old, new, args.threshold, gated=gated)

    print(f"{'row':40s} {'old_ms':>10s} {'new_ms':>10s} {'ratio':>7s}")
    for name, (o, n, ratio) in rep["common"].items():
        flag = ("  REGRESS" if name in rep["regressed"]
                else "  IMPROVE" if name in rep["improved"] else "")
        gate = " [gated]" if name in gated and flag else ""
        print(f"{name:40s} {o:10.3f} {n:10.3f} {ratio:6.2f}x{flag}{gate}")
    for name in rep["added"]:
        print(f"{name:40s} {'-':>10s} {new[name]:10.3f}   added")
    for name in rep["removed"]:
        print(f"{name:40s} {old[name]:10.3f} {'-':>10s}   removed")
    for label, errs in (("old", old_err), ("new", new_err)):
        if errs:
            print(f"# {label}: errored suites (no rows): {errs}")
    print(f"# {len(rep['common'])} compared ({len(gated)} gated), "
          f"{len(rep['regressed'])} regressed (> {args.threshold:.2f}x, "
          f"{len(rep['gated_regressed'])} gated), "
          f"{len(rep['improved'])} improved, {len(rep['added'])} added, "
          f"{len(rep['removed'])} removed")
    if args.fail_on_regress and rep["gated_regressed"]:
        print(f"# FAIL: gated rows regressed: {rep['gated_regressed']}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:      # output piped into head/less and closed
        sys.exit(0)
