"""Plot the recall/latency Pareto frontier from BENCH_*.json snapshots.

    python tools/pareto_plot.py BENCH_quick.json [OLD.json] [--svg out.svg]

Reads the ``pareto/*`` rows written by ``benchmarks/bench_pareto.py``
(``benchmarks.run --only pareto --json ...``) and renders recall@10
(x, higher better) against paced p99 ms (y, log-ish lower better) as an
ASCII scatter — frontier configs as ``O``, dominated ones as ``·`` —
plus the frontier staircase.  With a second snapshot the old frontier
is overlaid (``o``/``,``) so a frontier *shift* between two PRs is
visible in the terminal.  ``--svg`` additionally writes a small
self-contained SVG (no plotting deps — CI archives it next to the
JSON).

Exit code 2 when a snapshot has no pareto rows.
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from typing import List, Tuple

WIDTH, HEIGHT = 64, 20


def load_pareto(path: str) -> List[dict]:
    """[{name, recall, p99_ms, frontier}] from one snapshot's pareto/*
    rows (recall/frontier are parsed out of the row note)."""
    with open(path) as f:
        suites = json.load(f)
    out = []
    for suite in suites:
        for row in suite.get("rows", []):
            if not row["name"].startswith("pareto/"):
                continue
            note = row.get("note", "")
            recall = re.search(r"recall=([0-9.]+)", note)
            if not recall:
                continue
            out.append({
                "name": row["name"],
                "recall": float(recall.group(1)),
                "p99_ms": float(row["ms"]),
                "frontier": "frontier=True" in note,
            })
    return out


def _bounds(pts: List[dict]) -> Tuple[float, float, float, float]:
    rs = [p["recall"] for p in pts]
    ys = [p["p99_ms"] for p in pts]
    r0, r1 = min(rs), max(rs)
    y0, y1 = min(ys), max(ys)
    if r1 - r0 < 1e-9:
        r0, r1 = r0 - 0.05, r1 + 0.05
    if y1 - y0 < 1e-9:
        y0, y1 = y0 * 0.9, y1 * 1.1 or 1.0
    return r0, r1, y0, y1


def ascii_plot(new: List[dict], old: List[dict]) -> str:
    r0, r1, y0, y1 = _bounds(new + old)
    grid = [[" "] * WIDTH for _ in range(HEIGHT)]

    def put(p, mark_front, mark_dom):
        x = int((p["recall"] - r0) / (r1 - r0) * (WIDTH - 1))
        y = int((p["p99_ms"] - y0) / (y1 - y0) * (HEIGHT - 1))
        y = HEIGHT - 1 - y                      # low latency at the bottom
        grid[y][x] = mark_front if p["frontier"] else mark_dom

    for p in old:
        put(p, "o", ",")
    for p in new:                               # new overdraws old
        put(p, "O", "·")

    lines = [f"p99_ms  {y1:8.2f} ┐"]
    for i, g in enumerate(grid):
        prefix = "                "
        if i == HEIGHT - 1:
            prefix = f"        {y0:8.2f} ┘"
        lines.append(prefix[:16] + "│" + "".join(g))
    lines.append(" " * 16 + "└" + "─" * WIDTH)
    lines.append(f"{'':16} {r0:<10.3f}{'recall@10':^{WIDTH - 20}}"
                 f"{r1:>8.3f}")
    legend = "O frontier  · dominated"
    if old:
        legend += "  (o/, = old snapshot)"
    lines.append(" " * 17 + legend)
    return "\n".join(lines)


def svg_plot(new: List[dict], old: List[dict]) -> str:
    """Self-contained SVG: frontier staircase + config dots."""
    w, h, pad = 480, 300, 42
    r0, r1, y0, y1 = _bounds(new + old)

    def xy(p):
        x = pad + (p["recall"] - r0) / (r1 - r0) * (w - 2 * pad)
        y = h - pad - (p["p99_ms"] - y0) / (y1 - y0) * (h - 2 * pad)
        return x, y

    parts = [f'<svg xmlns="http://www.w3.org/2000/svg" width="{w}" '
             f'height="{h}" font-family="monospace" font-size="10">',
             f'<rect width="{w}" height="{h}" fill="white"/>',
             f'<line x1="{pad}" y1="{h - pad}" x2="{w - pad}" '
             f'y2="{h - pad}" stroke="black"/>',
             f'<line x1="{pad}" y1="{pad}" x2="{pad}" y2="{h - pad}" '
             f'stroke="black"/>',
             f'<text x="{w // 2}" y="{h - 8}" text-anchor="middle">'
             f'recall@10 ({r0:.3f} – {r1:.3f})</text>',
             f'<text x="12" y="{h // 2}" transform="rotate(-90 12 '
             f'{h // 2})" text-anchor="middle">paced p99 ms '
             f'({y0:.2f} – {y1:.2f})</text>']
    for pts, color, alpha in ((old, "#999999", 0.7),
                              (new, "#1f77b4", 1.0)):
        frontier = sorted((p for p in pts if p["frontier"]),
                          key=lambda p: p["recall"])
        if frontier:
            d = " ".join(f"{xy(p)[0]:.1f},{xy(p)[1]:.1f}"
                         for p in frontier)
            parts.append(f'<polyline points="{d}" fill="none" '
                         f'stroke="{color}" stroke-opacity="{alpha}"/>')
        for p in pts:
            x, y = xy(p)
            r = 4 if p["frontier"] else 2.5
            parts.append(f'<circle cx="{x:.1f}" cy="{y:.1f}" r="{r}" '
                         f'fill="{color}" fill-opacity="{alpha}">'
                         f'<title>{p["name"]}: recall='
                         f'{p["recall"]:.3f} p99={p["p99_ms"]:.2f}ms'
                         f'</title></circle>')
    parts.append("</svg>")
    return "\n".join(parts)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("new", help="BENCH_*.json with pareto/* rows")
    ap.add_argument("old", nargs="?", default=None,
                    help="optional older snapshot to overlay")
    ap.add_argument("--svg", metavar="PATH",
                    help="also write the frontier as a standalone SVG")
    args = ap.parse_args()

    new = load_pareto(args.new)
    if not new:
        print(f"# {args.new}: no pareto/* rows (run benchmarks.run "
              f"--only pareto --json first)", file=sys.stderr)
        return 2
    old = load_pareto(args.old) if args.old else []

    print(ascii_plot(new, old))
    n_front = sum(p["frontier"] for p in new)
    print(f"# {len(new)} configs, {n_front} on the frontier "
          f"({args.new})")
    for p in sorted(new, key=lambda p: p["recall"]):
        mark = "O" if p["frontier"] else " "
        print(f"#  {mark} {p['name']:24s} recall={p['recall']:.3f} "
              f"p99={p['p99_ms']:8.2f}ms")
    if args.svg:
        with open(args.svg, "w") as f:
            f.write(svg_plot(new, old))
        print(f"# wrote {args.svg}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
