#!/usr/bin/env python
"""Offline markdown link checker for the docs job.

Usage: python tools/check_md_links.py README.md docs [more files/dirs...]

Checks every relative ``[text](target)`` link in the given markdown
files (directories are walked for ``*.md``): the target file must exist
relative to the file containing the link, and a ``#fragment`` pointing
into a markdown file must match a heading's GitHub-style anchor.
External (``http(s)://``, ``mailto:``) links are skipped — CI has no
network, and their rot is not doc/API drift.

Exit code 0 iff every link resolves; broken links are listed one per
line as ``file:line: target``.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")


def github_anchor(heading: str) -> str:
    """GitHub's heading -> anchor slug (lowercase, spaces -> dashes,
    punctuation dropped)."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.replace(" ", "-")


def anchors_of(md_file: Path) -> set:
    out = set()
    for line in md_file.read_text(encoding="utf-8").splitlines():
        m = HEADING_RE.match(line)
        if m:
            out.add(github_anchor(m.group(1)))
    return out


def collect_md(paths) -> list:
    files = []
    for p in map(Path, paths):
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.suffix == ".md":
            files.append(p)
        else:
            print(f"warning: skipping non-markdown arg {p}", file=sys.stderr)
    return files


def check(files) -> list:
    broken = []
    for md in files:
        for lineno, line in enumerate(
                md.read_text(encoding="utf-8").splitlines(), 1):
            for target in LINK_RE.findall(line):
                if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # scheme
                    continue
                path_part, _, frag = target.partition("#")
                dest = (md.parent / path_part).resolve() if path_part else md
                if not dest.exists():
                    broken.append(f"{md}:{lineno}: {target}")
                    continue
                if frag and dest.suffix == ".md":
                    if github_anchor(frag) not in anchors_of(dest):
                        broken.append(f"{md}:{lineno}: {target} "
                                      f"(missing anchor)")
    return broken


def main() -> int:
    args = sys.argv[1:]
    if not args:
        print(__doc__)
        return 2
    files = collect_md(args)
    if not files:
        print("no markdown files found", file=sys.stderr)
        return 2
    broken = check(files)
    for b in broken:
        print(b)
    print(f"checked {len(files)} files: "
          f"{'OK' if not broken else f'{len(broken)} broken links'}")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
